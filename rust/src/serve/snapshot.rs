//! The frozen serving snapshot: a finished clustering packaged for
//! online queries.
//!
//! A [`ClusteredCorpus`] owns everything the query path needs — the
//! feature-space corpus, the final assignment, the **frozen** mean set
//! (every centroid marked invariant, see [`MeanSet::freeze`]), the
//! per-object ρ values, and per-cluster posting lists of member
//! documents (a counting-sorted CSR over clusters, the same layout the
//! update step uses internally). It also keeps the inverse of the
//! df-ascending term relabeling so raw bag-of-words queries in the
//! *original* vocabulary can be embedded into the frozen tf-idf feature
//! space ([`ClusteredCorpus::embed_bow`]).
//!
//! [`Query`] is the sparse unit-norm query vector consumed by
//! [`crate::serve::Router`]: ascending term ids, nonnegative values
//! (the tf-idf feature space is nonnegative, and the ES upper-bound
//! argument requires it), out-of-vocabulary terms dropped at
//! construction.

use crate::algo::ClusterOutput;
use crate::coordinator::MiniBatchOutput;
use crate::error::{SkmError, SkmResult};
use crate::index::{update_means, MeanSet};
use crate::persist::mmap::DiskRows;
use crate::sparse::{CsrMatrix, Dataset};
use std::sync::Arc;

/// A sparse query vector in the frozen corpus feature space.
///
/// Invariants (enforced by the constructors): term ids ascending and
/// `< d`, values finite and nonnegative, L2 norm 1 (or 0 for the empty
/// query — a zero vector routes deterministically to the lowest-id
/// centroids with score 0).
#[derive(Debug, Clone, PartialEq)]
pub struct Query {
    d: usize,
    ids: Vec<u32>,
    vals: Vec<f64>,
}

impl Query {
    /// Build from `(term id, weight)` pairs in the *relabeled* (feature
    /// space) vocabulary: out-of-vocabulary ids (`>= d`) and zero
    /// weights are dropped, duplicates summed, the result sorted and
    /// L2-normalized. Rejects negative, NaN, or infinite weights with a
    /// typed [`SkmError::InvalidQuery`] (never panics, never produces a
    /// non-unit vector) — the tf-idf feature space is nonnegative and
    /// the router's Region-3 upper bound (`u·v ≤ u·v_th` for `v < v_th`)
    /// relies on that. Use [`Query::from_pairs_strict`] to also reject
    /// OOV ids and zero weights instead of dropping them.
    pub fn from_pairs(d: usize, pairs: &[(u32, f64)]) -> SkmResult<Self> {
        // Validate every pair — including OOV ones — before dropping
        // anything: a NaN at an OOV id is still a malformed query, not
        // a silently-empty one.
        for &(t, v) in pairs {
            if !v.is_finite() || v < 0.0 {
                return Err(SkmError::invalid_query(format!(
                    "weight at term {t} must be finite and nonnegative (got {v})"
                )));
            }
        }
        let kept: Vec<(u32, f64)> = pairs
            .iter()
            .filter(|&&(t, v)| (t as usize) < d && v != 0.0)
            .copied()
            .collect();
        // Route through CsrMatrix::from_rows so duplicate summing and
        // sorting follow the exact float sequence build_dataset uses —
        // embed_bow'ing a corpus document reproduces its row bits.
        let m = CsrMatrix::from_rows(d, &[kept]);
        let (ids, vals) = m.row(0);
        let mut vals = vals.to_vec();
        let norm = vals.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm > 0.0 {
            for v in &mut vals {
                *v /= norm;
            }
        }
        Ok(Self {
            d,
            ids: ids.to_vec(),
            vals,
        })
    }

    /// Strict variant of [`Query::from_pairs`] for callers that treat
    /// lenient dropping as data loss: additionally rejects
    /// out-of-vocabulary term ids (`>= d`) and zero weights with typed
    /// errors. On acceptance the result is bit-identical to
    /// [`Query::from_pairs`] on the same input.
    pub fn from_pairs_strict(d: usize, pairs: &[(u32, f64)]) -> SkmResult<Self> {
        for &(t, v) in pairs {
            if (t as usize) >= d {
                return Err(SkmError::invalid_query(format!(
                    "term id {t} out of range (vocabulary size {d})"
                )));
            }
            if v == 0.0 {
                return Err(SkmError::invalid_query(format!(
                    "zero weight at term {t} (strict mode rejects silent drops)"
                )));
            }
        }
        Self::from_pairs(d, pairs)
    }

    /// A corpus document as a query (rows are already unit-norm or zero).
    pub fn from_row(ds: &Dataset, i: usize) -> Self {
        let (ts, vs) = ds.x.row(i);
        Self {
            d: ds.d(),
            ids: ts.to_vec(),
            vals: vs.to_vec(),
        }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn nnz(&self) -> usize {
        self.ids.len()
    }

    /// True for the zero vector (all terms were OOV or zero-weighted).
    pub fn is_zero(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    pub fn vals(&self) -> &[f64] {
        &self.vals
    }

    /// Split at the structural term threshold, like
    /// [`CsrMatrix::row_split`]: `(low, high)` slices with ids `< t_th`
    /// and `>= t_th`.
    pub fn split(&self, t_th: usize) -> ((&[u32], &[f64]), (&[u32], &[f64])) {
        let p0 = self.ids.partition_point(|&t| (t as usize) < t_th);
        (
            (&self.ids[..p0], &self.vals[..p0]),
            (&self.ids[p0..], &self.vals[p0..]),
        )
    }
}

/// A finished clustering frozen for serving. See the module docs.
#[derive(Debug, Clone)]
pub struct ClusteredCorpus {
    /// The corpus in feature space (unit-norm tf-idf rows).
    pub ds: Dataset,
    /// Final assignment a(i).
    pub assign: Vec<u32>,
    pub k: usize,
    /// Frozen mean set: recomputed from the assignment, unit-norm,
    /// every centroid marked invariant.
    pub means: MeanSet,
    /// Exact similarity of each document to its own centroid.
    pub rho: Vec<f64>,
    /// Clustering objective J = Σ_i ρ_{a(i)} over the frozen state.
    pub objective: f64,
    /// Per-cluster member posting lists (counting-sorted CSR layout).
    member_offsets: Vec<usize>,
    member_ids: Vec<u32>,
    /// Original term id → relabeled feature-space id (`u32::MAX` when
    /// the original term never occurred in the corpus).
    orig_to_term: Vec<u32>,
    /// When serving from a compressed snapshot via mmap
    /// ([`crate::persist::load_snapshot_mmap`]): the disk-backed corpus
    /// row reader. `ds.x` is then an empty stub of the right shape and
    /// every corpus row access must go through [`Self::row_view`].
    /// `None` for every in-RAM snapshot.
    disk: Option<Arc<DiskRows>>,
}

impl ClusteredCorpus {
    /// Freeze an assignment over `ds` into a serving snapshot. The mean
    /// set is recomputed from the assignment (deterministic: the same
    /// per-cluster float sequence as the update step), so any source of
    /// assignments — full-batch, mini-batch, or external — yields a
    /// self-consistent snapshot.
    pub fn from_assignment(ds: Dataset, assign: Vec<u32>, k: usize) -> Self {
        let n = ds.n();
        assert_eq!(assign.len(), n, "assignment length != corpus size");
        assert!(k >= 1, "need at least one cluster");
        assert!(
            assign.iter().all(|&a| (a as usize) < k),
            "assignment id out of range (K={k})"
        );
        let upd = update_means(&ds, &assign, k, None, None);
        let mut means = upd.means;
        means.freeze();

        // Counting sort of members by cluster (two passes, no
        // per-cluster Vec allocations — the update step's layout).
        let mut sizes = vec![0usize; k];
        for &a in &assign {
            sizes[a as usize] += 1;
        }
        let mut member_offsets = vec![0usize; k + 1];
        for j in 0..k {
            member_offsets[j + 1] = member_offsets[j] + sizes[j];
        }
        let mut member_ids = vec![0u32; n];
        let mut cursor = member_offsets.clone();
        for (i, &a) in assign.iter().enumerate() {
            member_ids[cursor[a as usize]] = i as u32;
            cursor[a as usize] += 1;
        }

        // Inverse relabeling for embed_bow.
        let max_orig = ds
            .orig_term
            .iter()
            .max()
            .map(|&t| t as usize + 1)
            .unwrap_or(0);
        let mut orig_to_term = vec![u32::MAX; max_orig];
        for (new_id, &old_id) in ds.orig_term.iter().enumerate() {
            orig_to_term[old_id as usize] = new_id as u32;
        }

        Self {
            ds,
            assign,
            k,
            means,
            rho: upd.rho,
            objective: upd.objective,
            member_offsets,
            member_ids,
            orig_to_term,
            disk: None,
        }
    }

    /// Snapshot a full-batch clustering run.
    pub fn from_output(ds: Dataset, out: &ClusterOutput, k: usize) -> Self {
        Self::from_assignment(ds, out.assign.clone(), k)
    }

    /// Snapshot a mini-batch / streaming run.
    pub fn from_minibatch(ds: Dataset, out: &MiniBatchOutput, k: usize) -> Self {
        Self::from_assignment(ds, out.assign.clone(), k)
    }

    /// Member document ids of cluster `j` (ascending).
    #[inline]
    pub fn members(&self, j: usize) -> &[u32] {
        &self.member_ids[self.member_offsets[j]..self.member_offsets[j + 1]]
    }

    /// The private posting/relabeling arrays `(member_offsets,
    /// member_ids, orig_to_term)`, for the persistence serializer. The
    /// snapshot stores these verbatim instead of recomputing them on
    /// load — the round-trip contract is "same stored state", not "same
    /// recomputation".
    pub(crate) fn persisted_parts(&self) -> (&[usize], &[u32], &[u32]) {
        (&self.member_offsets, &self.member_ids, &self.orig_to_term)
    }

    /// Reassemble a snapshot from fully-validated parts (the persistence
    /// reader's constructor). Private to the crate: the reader has
    /// already proven every structural invariant (`assign[i] < k`,
    /// member lists an ascending partition consistent with `assign`,
    /// `orig_to_term` inverse-consistent with `ds.orig_term`, ρ finite)
    /// with typed errors; this constructor only debug-asserts the
    /// cheapest of them as a belt-and-braces tripwire.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_validated_parts(
        ds: Dataset,
        assign: Vec<u32>,
        k: usize,
        means: MeanSet,
        rho: Vec<f64>,
        objective: f64,
        member_offsets: Vec<usize>,
        member_ids: Vec<u32>,
        orig_to_term: Vec<u32>,
    ) -> Self {
        debug_assert_eq!(assign.len(), ds.n());
        debug_assert_eq!(member_offsets.len(), k + 1);
        debug_assert_eq!(member_ids.len(), ds.n());
        Self {
            ds,
            assign,
            k,
            means,
            rho,
            objective,
            member_offsets,
            member_ids,
            orig_to_term,
            disk: None,
        }
    }

    /// Switch corpus row access to a disk-backed reader (the mmap
    /// loader's last step). The caller must have built `ds.x` as the
    /// empty stub — the reader is the only source of corpus postings
    /// from here on.
    pub(crate) fn attach_disk(&mut self, rows: Arc<DiskRows>) {
        debug_assert_eq!(rows.n_rows(), self.ds.n());
        debug_assert_eq!(self.ds.x.nnz(), 0, "attach_disk over a resident corpus");
        self.disk = Some(rows);
    }

    /// True when corpus rows are served from disk (mmap + block cache)
    /// rather than resident memory.
    pub fn is_disk_backed(&self) -> bool {
        self.disk.is_some()
    }

    /// `(cache hits, cache misses)` of the disk reader's block cache;
    /// `(0, 0)` for in-RAM snapshots.
    pub fn disk_cache_counters(&self) -> (u64, u64) {
        self.disk.as_ref().map_or((0, 0), |d| d.cache_counters())
    }

    /// Corpus row `i` as `(term ids, values)`. In-RAM snapshots borrow
    /// straight from the CSR; disk-backed snapshots decode the row's
    /// chunks through the block cache into the caller's scratch buffers
    /// and borrow from those. Decoded bits equal the saved bits either
    /// way, so downstream dot products are bit-identical across the two
    /// paths.
    #[inline]
    pub fn row_view<'a>(
        &'a self,
        i: usize,
        bytes: &mut Vec<u8>,
        ids: &'a mut Vec<u32>,
        vals: &'a mut Vec<f64>,
    ) -> (&'a [u32], &'a [f64]) {
        match &self.disk {
            None => self.ds.x.row(i),
            Some(rows) => {
                rows.fill_row(i, bytes, ids, vals);
                (ids, vals)
            }
        }
    }

    /// Corpus document `i` as a [`Query`], valid for both in-RAM and
    /// disk-backed snapshots (rows are already unit-norm or zero).
    /// Prefer this over [`Query::from_row`] when the snapshot may have
    /// come from [`crate::persist::load_snapshot_mmap`] — the raw CSR
    /// accessor would read the empty stub there.
    pub fn query_from_row(&self, i: usize) -> Query {
        let (mut b, mut ids, mut vals) = (Vec::new(), Vec::new(), Vec::new());
        let (ts, vs) = self.row_view(i, &mut b, &mut ids, &mut vals);
        Query {
            d: self.ds.d(),
            ids: ts.to_vec(),
            vals: vs.to_vec(),
        }
    }

    /// Embed a raw bag-of-words document — `(original term id, count)`
    /// pairs, e.g. straight out of [`crate::corpus::read_uci_bow`] — into
    /// the frozen tf-idf feature space: original ids are mapped through
    /// the df-ascending relabeling (unknown terms dropped as OOV),
    /// weighted by `count · ln(N / df)` with the *corpus* document
    /// frequencies, and L2-normalized. Embedding a corpus document
    /// reproduces its dataset row bit for bit (up to dropped
    /// zero-weight ubiquitous terms, which never change a score bit).
    ///
    /// Raw counts are `u32`, so the only invalid inputs are structural
    /// (a count so large `c · idf` overflows to infinity); those surface
    /// as a typed [`SkmError::InvalidQuery`] from [`Query::from_pairs`]
    /// rather than a panic or a non-unit vector.
    pub fn embed_bow(&self, doc: &[(u32, u32)]) -> SkmResult<Query> {
        let n_f = self.ds.n() as f64;
        let pairs: Vec<(u32, f64)> = doc
            .iter()
            .filter(|&&(_, c)| c > 0)
            .filter_map(|&(t, c)| {
                let nt = *self.orig_to_term.get(t as usize)?;
                if nt == u32::MAX {
                    return None;
                }
                let idf = (n_f / self.ds.df[nt as usize] as f64).ln();
                Some((nt, c as f64 * idf))
            })
            .collect();
        Query::from_pairs(self.ds.d(), &pairs)
    }

    /// Approximate resident bytes of the snapshot (corpus CSR + means +
    /// member lists + relabeling table). For a disk-backed snapshot the
    /// corpus stub contributes ~nothing and the disk reader's resident
    /// state (chunk metadata + block cache at capacity) is counted
    /// instead — the mmap'd file itself is page cache, not anonymous
    /// memory.
    pub fn mem_bytes(&self) -> usize {
        use std::mem::size_of;
        let csr = |m: &CsrMatrix| {
            m.nnz() * (size_of::<u32>() + size_of::<f64>())
                + (m.n_rows() + 1) * size_of::<usize>()
        };
        csr(&self.ds.x)
            + self.means.m.mem_bytes()
            + self.assign.len() * size_of::<u32>()
            + self.rho.len() * size_of::<f64>()
            + self.member_offsets.len() * size_of::<usize>()
            + self.member_ids.len() * size_of::<u32>()
            + self.orig_to_term.len() * size_of::<u32>()
            + self.disk.as_ref().map_or(0, |d| d.resident_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny};
    use crate::sparse::build_dataset;

    fn snapshot() -> (ClusteredCorpus, Vec<Vec<(u32, u32)>>) {
        let c = generate(&tiny(77));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let n = ds.n();
        let k = 7;
        let assign: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
        (
            ClusteredCorpus::from_assignment(ds, assign, k),
            c.docs.clone(),
        )
    }

    #[test]
    fn members_partition_the_corpus() {
        let (snap, _) = snapshot();
        let mut seen = vec![false; snap.ds.n()];
        for j in 0..snap.k {
            for &i in snap.members(j) {
                assert_eq!(snap.assign[i as usize], j as u32);
                assert!(!seen[i as usize], "doc {i} listed twice");
                seen[i as usize] = true;
            }
            // ascending within each cluster
            assert!(snap.members(j).windows(2).all(|w| w[0] < w[1]));
        }
        assert!(seen.iter().all(|&s| s), "member lists miss documents");
    }

    #[test]
    fn means_are_frozen_and_unit_norm() {
        let (snap, _) = snapshot();
        assert_eq!(snap.means.n_moving(), 0);
        for j in 0..snap.k {
            let norm = snap.means.m.row_norm(j);
            assert!(
                norm == 0.0 || (norm - 1.0).abs() < 1e-9,
                "mean {j} norm {norm}"
            );
        }
        assert!(snap.objective.is_finite());
        assert_eq!(snap.rho.len(), snap.ds.n());
    }

    #[test]
    fn query_from_pairs_normalizes_and_drops_oov() {
        let q = Query::from_pairs(4, &[(1, 3.0), (9, 5.0), (1, 1.0), (0, 0.0)]).unwrap();
        assert_eq!(q.ids(), &[1]);
        assert!((q.vals()[0] - 1.0).abs() < 1e-12); // 4.0 normalized
        assert!(!q.is_zero());
        let z = Query::from_pairs(4, &[(7, 2.0)]).unwrap();
        assert!(z.is_zero(), "OOV-only query must be the zero vector");
        let ((l, _), (h, _)) = q.split(2);
        assert_eq!(l, &[1]);
        assert!(h.is_empty());
    }

    #[test]
    fn query_rejects_bad_weights_with_typed_errors() {
        for bad in [-1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let err = Query::from_pairs(4, &[(1, bad)]).unwrap_err();
            match err {
                SkmError::InvalidQuery { detail } => {
                    assert!(detail.contains("finite and nonnegative"), "{detail}")
                }
                other => panic!("wrong variant for {bad}: {other:?}"),
            }
        }
        // Invalid weights at OOV ids are still rejected, not dropped.
        assert!(Query::from_pairs(4, &[(9, f64::NAN)]).is_err());
    }

    #[test]
    fn strict_query_rejects_what_lenient_drops() {
        assert!(Query::from_pairs_strict(4, &[(9, 1.0)]).is_err(), "OOV id");
        assert!(Query::from_pairs_strict(4, &[(1, 0.0)]).is_err(), "zero weight");
        let s = Query::from_pairs_strict(4, &[(1, 3.0), (2, 4.0)]).unwrap();
        let l = Query::from_pairs(4, &[(1, 3.0), (2, 4.0)]).unwrap();
        assert_eq!(s, l, "strict acceptance must be bit-identical to lenient");
    }

    #[test]
    fn embed_bow_reproduces_corpus_rows() {
        let (snap, docs) = snapshot();
        for i in [0usize, 3, 10] {
            let q = snap.embed_bow(&docs[i]).unwrap();
            let r = Query::from_row(&snap.ds, i);
            // The embedded query may drop zero-weight (idf = 0) terms
            // the row keeps explicitly; every kept value must match the
            // row's bits and the dropped ones must be zeros.
            let mut qi = 0usize;
            for (&t, &v) in r.ids().iter().zip(r.vals()) {
                if qi < q.ids().len() && q.ids()[qi] == t {
                    assert_eq!(v.to_bits(), q.vals()[qi].to_bits(), "doc {i} term {t}");
                    qi += 1;
                } else {
                    assert_eq!(v, 0.0, "doc {i} term {t} dropped but nonzero");
                }
            }
            assert_eq!(qi, q.ids().len(), "doc {i}: embedded terms not in row");
        }
    }

    #[test]
    fn mem_bytes_positive() {
        let (snap, _) = snapshot();
        assert!(snap.mem_bytes() > 0);
    }
}
