//! Batch query serving on the sharded `std::thread::scope` engine.
//!
//! Query serving is embarrassingly parallel over queries, exactly like
//! the assignment step is over objects ([`crate::algo::par`]): every
//! query's computation reads only the shared frozen [`Router`] (index +
//! means + corpus, all immutable for the whole batch) and writes only
//! its own result slot. The engine here mirrors `par::run_sharded`:
//! contiguous query shards on a shared work queue, workers pulling
//! shards as they finish, results landing in **per-query slots** so the
//! output order — and every score bit — is identical to the serial loop
//! regardless of which worker served which shard. Merged counters are
//! integer sums in fixed query order. `rust/tests/serve.rs` enforces
//! bit-identity across thread counts.
//!
//! Workers share the router's [`crate::algo::par::ScratchPool`]: each
//! checkout hands a worker a pooled K-length accumulator that stays hot
//! in its cache across the shard, and scratch contents are fully reset
//! per query, so pooling never affects results.
//!
//! ## Per-query fault containment (§Robustness)
//!
//! Every slot is a [`SkmResult`]: a query that panics mid-retrieval (or
//! returns a typed error, e.g. a vocabulary mismatch) fails **alone**.
//! The panic is caught per query under [`std::panic::catch_unwind`],
//! converted through [`SkmError::from_panic`], and stored in that
//! query's slot; the worker then continues with the next query on the
//! same (fully-reset-per-query) scratch, the queue locks are
//! poison-tolerant ([`lock_unpoisoned`]), and every unaffected query's
//! ids and score bits are identical to a fault-free run —
//! `rust/tests/faults.rs` proves it across threads 2/4/7.

use crate::algo::par::lock_unpoisoned;
use crate::algo::ParConfig;
use crate::error::{SkmError, SkmResult};
use crate::metrics::counters::OpCounters;
use crate::serve::router::{RouteScratch, Router, ServeResult};
use crate::serve::snapshot::Query;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Serve one contiguous query shard into its slots. `qi0` is the global
/// index of the shard's first query — the stable per-query address the
/// fail-point harness targets. Each query is individually contained: a
/// panic lands in that query's slot as a typed error and the loop moves
/// on.
fn serve_shard(
    router: &Router<'_>,
    s: &mut RouteScratch,
    qi0: usize,
    qs: &[Query],
    out: &mut [Option<SkmResult<ServeResult>>],
    top_p: usize,
    top_k: usize,
) {
    for (off, (q, slot)) in qs.iter().zip(out.iter_mut()).enumerate() {
        let qi = qi0 + off;
        let r = catch_unwind(AssertUnwindSafe(|| {
            crate::failpoint!("serve.query", qi);
            router.retrieve_with(s, q, top_p, top_k)
        }));
        *slot = Some(match r {
            Ok(res) => res,
            Err(payload) => Err(SkmError::from_panic("serve.query", payload)),
        });
    }
}

/// Serve a batch of queries: per-query results in query order (each the
/// exact [`Router::retrieve`] answer, or that query's typed error) plus
/// the counters merged over the successful queries. Bit-identical to
/// the serial loop for any `threads`/`shard` combination, including
/// under contained per-query faults (module docs). Use
/// [`serve_batch_strict`] when any failure should fail the whole batch.
pub fn serve_batch(
    router: &Router<'_>,
    queries: &[Query],
    top_p: usize,
    top_k: usize,
    par: &ParConfig,
) -> (Vec<SkmResult<ServeResult>>, OpCounters) {
    let n = queries.len();
    let mut slots: Vec<Option<SkmResult<ServeResult>>> = Vec::new();
    slots.resize_with(n, || None);

    if !par.is_parallel() || n == 0 {
        // One scratch for the whole batch (contents reset per query).
        let mut s = router.checkout_scratch();
        serve_shard(router, &mut s, 0, queries, &mut slots, top_p, top_k);
        router.checkin_scratch(s);
    } else {
        let shard = par.shard_size(n);
        let n_shards = (n + shard - 1) / shard;
        let threads = par.threads.min(n_shards).max(1);
        {
            // Shared work queue, exactly as in `par::run_sharded`:
            // scheduling varies run to run, the per-slot writes do not.
            let work: Vec<(usize, &[Query], &mut [Option<SkmResult<ServeResult>>])> = queries
                .chunks(shard)
                .zip(slots.chunks_mut(shard))
                .enumerate()
                .map(|(si, (qs, out))| (si * shard, qs, out))
                .collect();
            let queue = std::sync::Mutex::new(work);
            let queue = &queue;
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(move || loop {
                        let item = lock_unpoisoned(queue).pop();
                        match item {
                            Some((qi0, qs, out)) => {
                                // Scratch checked out per SHARD, not per
                                // query: the K-length accumulator stays
                                // hot in this worker's cache and the
                                // pool mutexes are off the per-query
                                // path (scratch is reset per query, so
                                // results are unaffected — including
                                // after a contained panic).
                                let mut s = router.checkout_scratch();
                                serve_shard(router, &mut s, qi0, qs, out, top_p, top_k);
                                router.checkin_scratch(s);
                            }
                            None => break,
                        }
                    });
                }
            });
        }
    }

    let results: Vec<SkmResult<ServeResult>> = slots
        .into_iter()
        .enumerate()
        .map(|(qi, r)| {
            // Structurally unreachable (serve_shard fills every slot of
            // every queue item), but a typed error beats an abort if a
            // future engine change breaks that.
            r.unwrap_or_else(|| {
                Err(SkmError::WorkerPanic {
                    site: "serve.slot".to_string(),
                    detail: format!("query {qi} left unserved"),
                })
            })
        })
        .collect();
    let mut total = OpCounters::new();
    for r in results.iter().flatten() {
        total.add(&r.counters);
    }
    (results, total)
}

/// All-or-nothing wrapper over [`serve_batch`]: the first failed
/// query's error fails the call (reported with its query index).
/// Convenient for offline/batch pipelines; online callers should use
/// [`serve_batch`] and handle per-query errors.
pub fn serve_batch_strict(
    router: &Router<'_>,
    queries: &[Query],
    top_p: usize,
    top_k: usize,
    par: &ParConfig,
) -> SkmResult<(Vec<ServeResult>, OpCounters)> {
    let (results, total) = serve_batch(router, queries, top_p, top_k, par);
    let mut ok = Vec::with_capacity(results.len());
    for (qi, r) in results.into_iter().enumerate() {
        match r {
            Ok(res) => ok.push(res),
            Err(e) => {
                return Err(SkmError::invalid_query(format!("query {qi} failed: {e}")))
            }
        }
    }
    Ok((ok, total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny};
    use crate::serve::router::RouterParams;
    use crate::serve::snapshot::ClusteredCorpus;
    use crate::sparse::build_dataset;

    /// Unit-scope smoke: parallel batch output equals the serial loop in
    /// order and bits. The full cross-thread suite (2/4/7 threads,
    /// estimated params, adversarial queries) lives in
    /// `rust/tests/serve.rs`; the fault-containment suite in
    /// `rust/tests/faults.rs`.
    #[test]
    fn batch_smoke_matches_serial() {
        let c = generate(&tiny(31));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let n = ds.n();
        let assign: Vec<u32> = (0..n).map(|i| (i % 5) as u32).collect();
        let snap = ClusteredCorpus::from_assignment(ds, assign, 5);
        let router = Router::new(&snap, RouterParams::exact()).unwrap();
        let queries: Vec<Query> = (0..17).map(|i| Query::from_row(&snap.ds, i * 3)).collect();
        let (serial, sc) =
            serve_batch_strict(&router, &queries, 2, 4, &ParConfig::serial()).unwrap();
        let (par, pc) = serve_batch_strict(
            &router,
            &queries,
            2,
            4,
            &ParConfig {
                threads: 3,
                shard: 4,
            },
        )
        .unwrap();
        assert_eq!(sc, pc);
        assert_eq!(serial.len(), par.len());
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.centroids.len(), b.centroids.len());
            for (x, y) in a.centroids.iter().zip(&b.centroids) {
                assert_eq!(x.0, y.0);
                assert_eq!(x.1.to_bits(), y.1.to_bits());
            }
            for (x, y) in a.hits.iter().zip(&b.hits) {
                assert_eq!(x.0, y.0);
                assert_eq!(x.1.to_bits(), y.1.to_bits());
            }
            assert_eq!(a.counters, b.counters);
        }
    }

    /// A wrong-vocabulary query fails alone: its slot is a typed error,
    /// every other slot is served.
    #[test]
    fn bad_query_fails_only_its_slot() {
        let c = generate(&tiny(32));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let n = ds.n();
        let assign: Vec<u32> = (0..n).map(|i| (i % 4) as u32).collect();
        let snap = ClusteredCorpus::from_assignment(ds, assign, 4);
        let router = Router::new(&snap, RouterParams::exact()).unwrap();
        let mut queries: Vec<Query> =
            (0..9).map(|i| Query::from_row(&snap.ds, i)).collect();
        // Query 4 claims a different vocabulary size.
        queries[4] = Query::from_pairs(snap.ds.d() + 5, &[(0, 1.0)]).unwrap();
        for par in [ParConfig::serial(), ParConfig { threads: 3, shard: 2 }] {
            let (results, _) = serve_batch(&router, &queries, 2, 3, &par);
            for (qi, r) in results.iter().enumerate() {
                if qi == 4 {
                    assert!(
                        matches!(r, Err(SkmError::InvalidQuery { .. })),
                        "query 4: {r:?}"
                    );
                } else {
                    assert!(r.is_ok(), "query {qi}: {r:?}");
                }
            }
        }
        assert!(serve_batch_strict(&router, &queries, 2, 3, &ParConfig::serial()).is_err());
    }
}
