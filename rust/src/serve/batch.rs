//! Batch query serving on the sharded `std::thread::scope` engine.
//!
//! Query serving is embarrassingly parallel over queries, exactly like
//! the assignment step is over objects ([`crate::algo::par`]): every
//! query's computation reads only the shared frozen [`Router`] (index +
//! means + corpus, all immutable for the whole batch) and writes only
//! its own result slot. The engine here mirrors `par::run_sharded`:
//! contiguous query shards on a shared work queue, workers pulling
//! shards as they finish, results landing in **per-query slots** so the
//! output order — and every score bit — is identical to the serial loop
//! regardless of which worker served which shard. Merged counters are
//! integer sums in fixed query order. `rust/tests/serve.rs` enforces
//! bit-identity across thread counts.
//!
//! Workers share the router's [`crate::algo::par::ScratchPool`]: each
//! checkout hands a worker a pooled K-length accumulator that stays hot
//! in its cache across the shard, and scratch contents are fully reset
//! per query, so pooling never affects results.

use crate::algo::ParConfig;
use crate::metrics::counters::OpCounters;
use crate::serve::router::{Router, ServeResult};
use crate::serve::snapshot::Query;

/// Serve a batch of queries: per-query results in query order (each the
/// exact [`Router::retrieve`] answer) plus the merged counters.
/// Bit-identical to the serial loop for any `threads`/`shard`
/// combination.
pub fn serve_batch(
    router: &Router<'_>,
    queries: &[Query],
    top_p: usize,
    top_k: usize,
    par: &ParConfig,
) -> (Vec<ServeResult>, OpCounters) {
    let n = queries.len();
    let mut slots: Vec<Option<ServeResult>> = Vec::new();
    slots.resize_with(n, || None);

    if !par.is_parallel() || n == 0 {
        // One scratch for the whole batch (contents reset per query).
        let mut s = router.checkout_scratch();
        for (q, slot) in queries.iter().zip(slots.iter_mut()) {
            *slot = Some(router.retrieve_with(&mut s, q, top_p, top_k));
        }
        router.checkin_scratch(s);
    } else {
        let shard = par.shard_size(n);
        let n_shards = (n + shard - 1) / shard;
        let threads = par.threads.min(n_shards).max(1);
        {
            // Shared work queue, exactly as in `par::run_sharded`:
            // scheduling varies run to run, the per-slot writes do not.
            let work: Vec<(&[Query], &mut [Option<ServeResult>])> = queries
                .chunks(shard)
                .zip(slots.chunks_mut(shard))
                .collect();
            let queue = std::sync::Mutex::new(work);
            let queue = &queue;
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(move || loop {
                        let item = queue.lock().unwrap().pop();
                        match item {
                            Some((qs, out)) => {
                                // Scratch checked out per SHARD, not per
                                // query: the K-length accumulator stays
                                // hot in this worker's cache and the
                                // pool mutexes are off the per-query
                                // path (scratch is reset per query, so
                                // results are unaffected).
                                let mut s = router.checkout_scratch();
                                for (q, slot) in qs.iter().zip(out.iter_mut()) {
                                    *slot =
                                        Some(router.retrieve_with(&mut s, q, top_p, top_k));
                                }
                                router.checkin_scratch(s);
                            }
                            None => break,
                        }
                    });
                }
            });
        }
    }

    let results: Vec<ServeResult> = slots
        .into_iter()
        .map(|r| r.expect("query slot left unserved"))
        .collect();
    let mut total = OpCounters::new();
    for r in &results {
        total.add(&r.counters);
    }
    (results, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny};
    use crate::serve::router::RouterParams;
    use crate::serve::snapshot::ClusteredCorpus;
    use crate::sparse::build_dataset;

    /// Unit-scope smoke: parallel batch output equals the serial loop in
    /// order and bits. The full cross-thread suite (2/4/7 threads,
    /// estimated params, adversarial queries) lives in
    /// `rust/tests/serve.rs`.
    #[test]
    fn batch_smoke_matches_serial() {
        let c = generate(&tiny(31));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let n = ds.n();
        let assign: Vec<u32> = (0..n).map(|i| (i % 5) as u32).collect();
        let snap = ClusteredCorpus::from_assignment(ds, assign, 5);
        let router = Router::new(&snap, RouterParams::exact());
        let queries: Vec<Query> = (0..17).map(|i| Query::from_row(&snap.ds, i * 3)).collect();
        let (serial, sc) = serve_batch(&router, &queries, 2, 4, &ParConfig::serial());
        let (par, pc) = serve_batch(
            &router,
            &queries,
            2,
            4,
            &ParConfig {
                threads: 3,
                shard: 4,
            },
        );
        assert_eq!(sc, pc);
        assert_eq!(serial.len(), par.len());
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.centroids.len(), b.centroids.len());
            for (x, y) in a.centroids.iter().zip(&b.centroids) {
                assert_eq!(x.0, y.0);
                assert_eq!(x.1.to_bits(), y.1.to_bits());
            }
            for (x, y) in a.hits.iter().zip(&b.hits) {
                assert_eq!(x.0, y.0);
                assert_eq!(x.1.to_bits(), y.1.to_bits());
            }
            assert_eq!(a.counters, b.counters);
        }
    }
}
