//! The online serving layer (§Serve tentpole): nearest-centroid query
//! routing and document retrieval over a finished clustering.
//!
//! Everything before this module clusters a corpus and discards the
//! result; this is the piece that answers queries against it — the
//! ROADMAP's traffic story. The pipeline:
//!
//! 1. [`ClusteredCorpus`] ([`snapshot`]) freezes a finished clustering:
//!    corpus + assignment + recomputed unit-norm means (every centroid
//!    marked invariant) + per-cluster member posting lists + the
//!    inverse term relabeling for embedding raw bag-of-words queries.
//! 2. [`Router`] ([`router`]) builds the three-region structured index
//!    over the frozen means and routes a sparse query to its top-p
//!    nearest centroids with **exact** cosine scores — the ES filter's
//!    folded upper-bound gather (through the [`crate::algo::kernel`]
//!    micro-kernels and the dense Region-1 tail) prunes the candidate
//!    set, and the result is bit-identical to a brute-force scan over
//!    all means (`rust/tests/serve.rs`).
//! 3. [`Router::retrieve`] scans only the routed clusters' member
//!    documents for the exact top-k nearest documents.
//! 4. [`serve_batch`] ([`batch`]) shards query batches over
//!    `std::thread::scope` exactly like the assignment engine
//!    ([`crate::algo::par`]) — per-query result slots keep the output
//!    (and every score bit) identical to the serial loop for any
//!    thread count.
//!
//! Failure is per request, never per process (§Robustness): every
//! `serve_batch` slot is a `Result`, so a hostile query or a panicking
//! worker fails alone — unaffected queries stay bit-identical to a
//! fault-free run — and the router degrades to its exact scan when the
//! pruned path fails internally (see [`router`]'s degradation section
//! and `rust/tests/faults.rs`).
//!
//! Plumbing: the `skm serve` subcommand (cluster → snapshot → route a
//! query file or synthetic batch, `--top-p`/`--top-k`/`--threads`),
//! `benches/serve.rs` (QPS / latency percentiles, bitwise-verified
//! batch vs serial), and `examples/serve.rs`.

pub mod batch;
pub mod report;
pub mod router;
pub mod snapshot;

pub use batch::{serve_batch, serve_batch_strict};
pub use report::{latency_stats, serve_run_json, LatencyStats};
pub use router::{push_top, Router, RouterParams, ServeResult, UB_GUARD};
pub use snapshot::{ClusteredCorpus, Query};

/// Default serving knobs for a K-cluster workload: route to roughly one
/// cluster per 32 (clamped to `[1, 8]`) and return ten documents — the
/// usual recall/latency middle ground for cluster-pruned retrieval.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeDefaults {
    pub top_p: usize,
    pub top_k: usize,
}

impl ServeDefaults {
    pub fn default_for(k: usize) -> Self {
        Self {
            top_p: ((k + 31) / 32).clamp(1, 8),
            top_k: 10,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_scale_with_k() {
        assert_eq!(ServeDefaults::default_for(1).top_p, 1);
        assert_eq!(ServeDefaults::default_for(32).top_p, 1);
        assert_eq!(ServeDefaults::default_for(64).top_p, 2);
        assert_eq!(ServeDefaults::default_for(10_000).top_p, 8);
        assert_eq!(ServeDefaults::default_for(64).top_k, 10);
    }
}
