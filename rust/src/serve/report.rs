//! Machine-readable serving reports: the `skm serve --bench-json` shape
//! and the latency-percentile helper shared with `benches/serve.rs`.

use crate::error::SkmResult;
use crate::metrics::counters::OpCounters;
use crate::serve::router::{Router, ServeResult};
use crate::serve::snapshot::ClusteredCorpus;
use crate::util::json::Json;

/// Latency summary over per-query wall times (seconds in, reported in
/// microseconds by [`serve_run_json`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyStats {
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

/// Compute latency percentiles (nearest-rank over the sorted samples).
/// Empty input yields zeros.
pub fn latency_stats(samples: &[f64]) -> LatencyStats {
    if samples.is_empty() {
        return LatencyStats::default();
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Nearest-rank percentile: the ceil(q·N)-th smallest sample.
    let pick = |q: f64| {
        let idx = ((q * sorted.len() as f64).ceil() as usize).saturating_sub(1);
        sorted[idx.min(sorted.len() - 1)]
    };
    LatencyStats {
        mean_s: sorted.iter().sum::<f64>() / sorted.len() as f64,
        p50_s: pick(0.50),
        p90_s: pick(0.90),
        p99_s: pick(0.99),
        max_s: *sorted.last().unwrap(),
    }
}

impl LatencyStats {
    fn json_us(&self) -> Json {
        Json::obj(vec![
            ("mean", Json::Num(self.mean_s * 1e6)),
            ("p50", Json::Num(self.p50_s * 1e6)),
            ("p90", Json::Num(self.p90_s * 1e6)),
            ("p99", Json::Num(self.p99_s * 1e6)),
            ("max", Json::Num(self.max_s * 1e6)),
        ])
    }
}

/// Machine-readable report for one served batch: dataset/router shape,
/// throughput, cost counters, optional latency percentiles, and the
/// per-query top-p/top-k answers. Consumed by `skm serve --bench-json`.
/// A failed query renders as `{"error": "<display>"}` in `per_query`
/// and is excluded from the counter/pruning aggregates; the top-level
/// `errors` field counts failures.
pub fn serve_run_json(
    snap: &ClusteredCorpus,
    router: &Router<'_>,
    top_p: usize,
    top_k: usize,
    threads: usize,
    results: &[SkmResult<ServeResult>],
    wall_secs: f64,
    latency: Option<&LatencyStats>,
) -> Json {
    let mut counters = OpCounters::new();
    for r in results.iter().flatten() {
        counters.add(&r.counters);
    }
    let n_err = results.iter().filter(|r| r.is_err()).count();
    let nq = results.len().max(1) as f64;
    let per_query: Vec<Json> = results
        .iter()
        .map(|res| match res {
            Err(e) => Json::obj(vec![("error", Json::str(e.to_string()))]),
            Ok(r) => Json::obj(vec![
                (
                    "centroids",
                    Json::Arr(
                        r.centroids
                            .iter()
                            .map(|&(c, s)| {
                                Json::obj(vec![
                                    ("cluster", Json::UInt(c as u64)),
                                    ("score", Json::Num(s)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "hits",
                    Json::Arr(
                        r.hits
                            .iter()
                            .map(|&(i, s)| {
                                Json::obj(vec![
                                    ("doc", Json::UInt(i as u64)),
                                    ("score", Json::Num(s)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        })
        .collect();
    Json::obj(vec![
        ("mode", Json::str("serve")),
        (
            "dataset",
            Json::obj(vec![
                ("name", Json::str(snap.ds.name.clone())),
                ("n", Json::UInt(snap.ds.n() as u64)),
                ("d", Json::UInt(snap.ds.d() as u64)),
                ("k", Json::UInt(snap.k as u64)),
            ]),
        ),
        (
            "router",
            Json::obj(vec![
                ("t_th", Json::UInt(router.t_th() as u64)),
                ("v_th", Json::Num(router.v_th())),
                ("index_mem_bytes", Json::UInt(router.mem_bytes() as u64)),
                ("snapshot_mem_bytes", Json::UInt(snap.mem_bytes() as u64)),
            ]),
        ),
        (
            "config",
            Json::obj(vec![
                ("top_p", Json::UInt(top_p as u64)),
                ("top_k", Json::UInt(top_k as u64)),
                ("threads", Json::UInt(threads as u64)),
            ]),
        ),
        ("queries", Json::UInt(results.len() as u64)),
        ("errors", Json::UInt(n_err as u64)),
        ("wall_secs", Json::Num(wall_secs)),
        (
            "qps",
            Json::Num(results.len() as f64 / wall_secs.max(1e-12)),
        ),
        (
            "pruning",
            Json::obj(vec![
                (
                    "avg_candidates_per_query",
                    Json::Num(counters.candidates as f64 / nq),
                ),
                (
                    "candidate_fraction",
                    Json::Num(counters.candidates as f64 / (nq * snap.k.max(1) as f64)),
                ),
            ]),
        ),
        (
            "counters",
            Json::obj(vec![
                ("mult", Json::UInt(counters.mult)),
                ("candidates", Json::UInt(counters.candidates)),
                ("exact_sims", Json::UInt(counters.exact_sims)),
            ]),
        ),
        (
            "latency_us",
            latency.map(|l| l.json_us()).unwrap_or(Json::Null),
        ),
        ("per_query", Json::Arr(per_query)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny};
    use crate::serve::router::RouterParams;
    use crate::serve::serve_batch;
    use crate::serve::snapshot::Query;
    use crate::sparse::build_dataset;

    #[test]
    fn latency_stats_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let l = latency_stats(&samples);
        assert_eq!(l.p50_s, 50.0);
        assert_eq!(l.p90_s, 90.0);
        assert_eq!(l.p99_s, 99.0);
        assert_eq!(l.max_s, 100.0);
        assert!((l.mean_s - 50.5).abs() < 1e-12);
        assert_eq!(latency_stats(&[]).max_s, 0.0);
    }

    #[test]
    fn serve_json_has_expected_fields() {
        let c = generate(&tiny(55));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let n = ds.n();
        let assign: Vec<u32> = (0..n).map(|i| (i % 4) as u32).collect();
        let snap = ClusteredCorpus::from_assignment(ds, assign, 4);
        let router = Router::new(&snap, RouterParams::exact()).unwrap();
        let mut queries: Vec<Query> = (0..5).map(|i| Query::from_row(&snap.ds, i)).collect();
        // One failing query: the report must carry it as an error entry
        // without dropping the successful ones.
        queries.push(Query::from_pairs(snap.ds.d() + 3, &[(0, 1.0)]).unwrap());
        let (results, _) = serve_batch(
            &router,
            &queries,
            2,
            3,
            &crate::algo::ParConfig::serial(),
        );
        let j = serve_run_json(&snap, &router, 2, 3, 1, &results, 0.5, None);
        let text = j.render();
        for key in [
            "\"mode\"",
            "\"serve\"",
            "\"router\"",
            "\"t_th\"",
            "\"qps\"",
            "\"pruning\"",
            "\"candidate_fraction\"",
            "\"per_query\"",
            "\"centroids\"",
            "\"hits\"",
            "\"errors\":1",
            "\"error\"",
        ] {
            assert!(text.contains(key), "missing {key}");
        }
    }
}
