//! Nearest-centroid query routing over the structured mean index, plus
//! the exact second-stage document retrieval.
//!
//! ## Routing = one-object assignment, generalized to top-p
//!
//! The paper's structural insight applies verbatim on the query side: a
//! query is just an object vector assigned to its nearest centroid(s),
//! so the same three-region machinery that accelerates the assignment
//! step routes queries. The [`Router`] builds an [`EsIndex`] over the
//! snapshot's **frozen** means (every centroid invariant — the moving
//! blocks are empty and every scan is the branch-free full pass) and
//! scores a query in two phases, reusing the [`crate::algo::kernel`]
//! micro-kernels end to end:
//!
//! 1. **Gather** (Regions 1 + 2): the folded upper-bound accumulation of
//!    the ES filter — ρ starts at the Region-3 mass
//!    `y = v_th · Σ_{s ≥ t_th} u_s`, Region 1 gathers through
//!    [`crate::index::InvIndex::gather_term`] (dense-tail FMA rows
//!    included), Region 2 through the unrolled unchecked scatter-add.
//!    After this phase
//!    `ρ_j` is an upper bound on the exact cosine `⟨q, μ_j⟩` (for
//!    Region-3 terms `u·v ≤ u·v_th` since `0 ≤ v < v_th` and `u ≥ 0`).
//! 2. **Verify**: take the `p` centroids with the largest upper bounds
//!    as seeds, compute their exact cosines, and let `τ` be the worst
//!    seed cosine — a provable lower bound on the true p-th best score
//!    (any p exact scores bound the p-th order statistic from below).
//!    Every centroid with `ρ_j < τ − ε` is pruned: its exact score is
//!    `≤ ρ_j < τ ≤` p-th best, so it cannot enter the top-p. Survivors
//!    are re-scored exactly and the final top-p selected by
//!    `(score desc, id asc)`.
//!
//! ## Exactness contract
//!
//! Exact scores are sparse merges in ascending term order
//! ([`dot_sorted_count`], the same float sequence as
//! [`crate::sparse::dot_sorted`]) — **bit-identical** to a dense
//! brute-force scan `Σ_s u_s · μ_j[s]` by the `+0.0`-padding argument of
//! [`crate::algo::kernel`]'s docs (query and mean values are
//! nonnegative, so accumulators never reach `-0.0`). Combined with the
//! total `(score desc, id asc)` order, the routed top-p list — ids *and*
//! score bits — equals the brute-force answer; `rust/tests/serve.rs`
//! fuzzes this across seeds, K, p, and degenerate queries. The guard
//! band [`UB_GUARD`] absorbs the float-rounding daylight between the
//! folded upper-bound accumulation and the exact merges (≈1e-16 per op;
//! the band only ever *adds* survivors, never drops one).
//!
//! The second stage, [`Router::retrieve`], scans only the routed
//! clusters' member documents with the same exact merge and returns the
//! top-k by the same total order — exact over the routed subset, also
//! pinned by `rust/tests/serve.rs` against a naive restricted scan.
//!
//! Per-query scratch (the K-length ρ accumulator and the seed list)
//! lives in a [`ScratchPool`], so steady-state routing allocates only
//! the returned result vectors.
//!
//! ## Graceful degradation (§Robustness)
//!
//! The exactness contract makes failure handling unusually clean: the
//! pruned path and the brute-force scan return the *same bits*, so when
//! the pruned path fails — parameter estimation dies, the structured
//! index is inconsistent with the snapshot, or a fail-point fires — the
//! router falls back to [`Router::route_exact`] (all-means sparse-merge
//! scan) with a logged reason instead of panicking, and the caller's
//! results are unchanged except for the cost counters. Invalid *queries*
//! (vocabulary mismatch) are the caller's error and are returned as
//! typed [`SkmError::InvalidQuery`] values, never degraded around.
//! [`Router::fallback_count`] exposes how often degradation engaged;
//! `rust/tests/faults.rs` pins the fallback's bit-parity.

use crate::algo::kernel;
use crate::algo::par::ScratchPool;
use crate::algo::ClusterConfig;
use crate::error::{SkmError, SkmResult};
use crate::estparams::EstConfig;
use crate::index::{EsIndex, ObjInvIndex, PartialIndex};
use crate::metrics::counters::OpCounters;
use crate::metrics::perf::PhaseTimes;
use crate::serve::snapshot::{ClusteredCorpus, Query};
use crate::util::log::log_once;
use std::mem::size_of;
use std::sync::atomic::{AtomicU64, Ordering};

/// Absolute guard band on the upper-bound prune (cosine scores live in
/// `[0, 1]`): a centroid survives when `ub ≥ τ − UB_GUARD`. Large
/// enough to absorb any float-rounding shortfall of the folded gather
/// against the exact merge, small enough to admit essentially no extra
/// survivors.
pub const UB_GUARD: f64 = 1e-9;

/// Push `(score, id)` into a bounded best-first list ordered by
/// `(score desc, id asc)` — the serving layer's one total order, shared
/// by routing, retrieval, and the test oracles. `top` stays sorted;
/// `cap == 0` keeps it empty.
#[inline]
pub fn push_top(top: &mut Vec<(f64, u32)>, cap: usize, score: f64, id: u32) {
    if cap == 0 {
        return;
    }
    let better = |s: f64, i: u32| s > score || (s == score && i < id);
    if top.len() == cap {
        let (ws, wi) = top[cap - 1];
        if better(ws, wi) {
            return;
        }
        top.pop();
    }
    let pos = top.partition_point(|&(s, i)| better(s, i));
    top.insert(pos, (score, id));
}

/// Sparse·sparse dot in strict ascending-term merge order — the float
/// sequence of [`crate::sparse::dot_sorted`] — returning the
/// multiplication count for the cost accounting.
#[inline]
fn dot_sorted_count(ta: &[u32], va: &[f64], tb: &[u32], vb: &[f64]) -> (f64, u64) {
    let (mut i, mut j, mut acc, mut m) = (0usize, 0usize, 0.0f64, 0u64);
    while i < ta.len() && j < tb.len() {
        match ta[i].cmp(&tb[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                acc += va[i] * vb[j];
                m += 1;
                i += 1;
                j += 1;
            }
        }
    }
    (acc, m)
}

/// Structural parameters of the routing index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouterParams {
    /// Region-1/2 term split (clamped to `D` at build).
    pub t_th: usize,
    /// Region-2 value threshold (must be positive; `1.0` with
    /// `t_th == D` is the exact MIVI-style full gather).
    pub v_th: f64,
}

impl RouterParams {
    /// The degenerate parameters: everything in Region 1, no pruning
    /// upper bound — an exact full gather (useful as a baseline and for
    /// tiny K where the filter cannot pay off).
    pub fn exact() -> Self {
        Self {
            t_th: usize::MAX,
            v_th: 1.0,
        }
    }

    /// Estimate `(t_th, v_th)` for the snapshot with the Section-V
    /// estimator over the frozen means and ρ (the same machinery the
    /// ES-ICP assigner runs at iterations 2–3). Falls back to
    /// [`RouterParams::exact`] for `K < 4`, where the probability model
    /// degenerates (same guard as the assigner) — and likewise when the
    /// estimator panics or returns unusable parameters: estimation is a
    /// performance optimization, so its failure degrades throughput,
    /// never availability or result bits (the exact parameters route
    /// every query correctly; module docs).
    pub fn estimate_for(snap: &ClusteredCorpus, cfg: &ClusterConfig) -> Self {
        let d = snap.ds.d();
        if snap.k < 4 {
            return Self::exact();
        }
        // A disk-backed snapshot's in-RAM corpus is an empty stub; the
        // estimator would silently model a corpus of zeros. Stored
        // parameters (or explicit overrides) are the supported source
        // there — degrade to exact, never to wrong estimates.
        if snap.is_disk_backed() {
            log_once(
                "router.estimate.disk",
                "parameter estimation needs the corpus in RAM; disk-backed snapshot \
                 serves with exact routing parameters (use the stored or explicit ones)",
            );
            return Self::exact();
        }
        let est = crate::error::contain("router.estimate", || {
            crate::failpoint!("router.estimate", 0u64);
            let s_min = ((d as f64 * cfg.s_min_frac) as usize).min(d.saturating_sub(1));
            let xp = ObjInvIndex::build(&snap.ds.x, s_min);
            let est = crate::estparams::estimate(
                &snap.ds,
                &snap.means,
                &snap.rho,
                &xp,
                &EstConfig {
                    s_min,
                    n_candidates: cfg.n_vth_candidates,
                    fixed_t: None,
                    fixed_v: None,
                    max_sample_objects: 4_000,
                },
            );
            Self {
                t_th: est.t_th,
                v_th: est.v_th,
            }
        });
        match est {
            Ok(p) if p.v_th.is_finite() && p.v_th > 0.0 => p,
            Ok(p) => {
                log_once(
                    "router.estimate.unusable",
                    &format!(
                        "parameter estimation returned unusable v_th={}; \
                         serving with exact routing parameters",
                        p.v_th
                    ),
                );
                Self::exact()
            }
            Err(e) => {
                log_once(
                    "router.estimate.failed",
                    &format!(
                        "parameter estimation failed ({e}); serving with exact routing parameters"
                    ),
                );
                Self::exact()
            }
        }
    }
}

/// Pooled per-worker scratch: the K-length folded upper-bound
/// accumulator and the seed list. Checked out once per shard by
/// [`crate::serve::serve_batch`] (so the accumulator stays hot in one
/// worker's cache across its whole shard, like the assignment engine's
/// scratch) and once per call by the public one-shot entry points.
/// Contents are fully reset per query, so pooling never affects
/// results.
#[derive(Default)]
pub(crate) struct RouteScratch {
    rho: Vec<f64>,
    seeds: Vec<(f64, u32)>,
    /// Row-decode scratch for disk-backed snapshots
    /// ([`ClusteredCorpus::row_view`]): chunk byte span, decoded term
    /// ids, decoded values. Unused (and never grown) when the corpus is
    /// resident in RAM.
    row_bytes: Vec<u8>,
    row_ids: Vec<u32>,
    row_vals: Vec<f64>,
}

impl RouteScratch {
    fn mem_bytes(&self) -> usize {
        self.rho.capacity() * size_of::<f64>()
            + self.seeds.capacity() * size_of::<(f64, u32)>()
            + self.row_bytes.capacity()
            + self.row_ids.capacity() * size_of::<u32>()
            + self.row_vals.capacity() * size_of::<f64>()
    }
}

/// One served query: routed centroids, retrieved documents (empty when
/// only routing was requested), and the cost counters.
#[derive(Debug, Clone)]
pub struct ServeResult {
    /// Top-p `(cluster id, exact cosine)`, best first.
    pub centroids: Vec<(u32, f64)>,
    /// Top-k `(document id, exact cosine)` over the routed clusters'
    /// members, best first.
    pub hits: Vec<(u32, f64)>,
    pub counters: OpCounters,
}

/// The online query router. See the module docs.
pub struct Router<'a> {
    snap: &'a ClusteredCorpus,
    params: RouterParams,
    idx: EsIndex,
    scratch: ScratchPool<RouteScratch>,
    /// How many queries were served by the exact-scan fallback because
    /// the pruned path failed (see the module's degradation section).
    fallbacks: AtomicU64,
}

impl<'a> Router<'a> {
    /// Build the routing index over the snapshot's frozen means.
    /// Rejects non-positive / non-finite `v_th` with a typed
    /// [`SkmError::InvalidConfig`].
    pub fn new(snap: &'a ClusteredCorpus, params: RouterParams) -> SkmResult<Self> {
        if !(params.v_th > 0.0 && params.v_th.is_finite()) {
            return Err(SkmError::invalid_config(format!(
                "v_th must be positive and finite (got {})",
                params.v_th
            )));
        }
        let params = RouterParams {
            t_th: params.t_th.min(snap.ds.d()),
            v_th: params.v_th,
        };
        let mut idx = EsIndex::build(&snap.means, params.t_th, params.v_th);
        // The ES verification phase retires Region-3 deficits through
        // the dense partial index M^p; the router instead re-scores
        // survivors by exact sparse merges (the bit-parity contract in
        // the module docs), so M^p — a (D − t_th) × K f64 matrix, by
        // far the largest piece of the structured index — is never
        // read. Drop it so the serving index holds (and reports) only
        // what routing uses.
        idx.partial = PartialIndex::default();
        Ok(Self {
            snap,
            params,
            idx,
            scratch: ScratchPool::new(),
            fallbacks: AtomicU64::new(0),
        })
    }

    pub fn t_th(&self) -> usize {
        self.params.t_th
    }

    pub fn v_th(&self) -> f64 {
        self.params.v_th
    }

    pub fn params(&self) -> RouterParams {
        self.params
    }

    pub fn snapshot(&self) -> &'a ClusteredCorpus {
        self.snap
    }

    /// Routing-index + pooled-scratch bytes (the snapshot accounts for
    /// itself via [`ClusteredCorpus::mem_bytes`]).
    pub fn mem_bytes(&self) -> usize {
        self.idx.mem_bytes() + self.scratch.mem_bytes(RouteScratch::mem_bytes)
    }

    /// Check out a pooled scratch for a run of queries (one per shard;
    /// see [`RouteScratch`]).
    pub(crate) fn checkout_scratch(&self) -> RouteScratch {
        self.scratch.checkout(RouteScratch::default)
    }

    /// Return a scratch to the pool.
    pub(crate) fn checkin_scratch(&self, s: RouteScratch) {
        self.scratch.checkin(s, PhaseTimes::default());
    }

    /// Queries served by the exact-scan fallback so far (0 in healthy
    /// operation; monitoring hook for the degradation path).
    pub fn fallback_count(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Record a pruned-path failure and log the first one (deduped
    /// process-wide by [`log_once`], not per router — a fleet of shard
    /// routers degrading for the same reason should not multiply the
    /// line; [`Router::fallback_count`] carries the per-router signal).
    fn note_fallback(&self, e: &SkmError) {
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
        log_once(
            "router.fallback",
            &format!("routing degraded to the exact scan ({e}); results are unaffected"),
        );
    }

    /// Route a query: the top-`p` centroids with **exact** cosine
    /// scores, best first under `(score desc, id asc)` — bit-identical
    /// to a brute-force scan over all means (module docs). `top_p` is
    /// clamped to `[1, K]`.
    ///
    /// `Err` is returned only for invalid queries (vocabulary
    /// mismatch); internal pruned-path failures degrade to the exact
    /// scan with identical result bits (module docs).
    pub fn route(&self, q: &Query, top_p: usize) -> SkmResult<(Vec<(u32, f64)>, OpCounters)> {
        let mut s = self.checkout_scratch();
        let out = self.route_with(&mut s, q, top_p);
        self.checkin_scratch(s);
        out
    }

    /// The per-query routing core, against caller-held scratch: pruned
    /// path first, exact-scan degradation on its failure (never on
    /// invalid queries — those are the caller's error).
    pub(crate) fn route_with(
        &self,
        s: &mut RouteScratch,
        q: &Query,
        top_p: usize,
    ) -> SkmResult<(Vec<(u32, f64)>, OpCounters)> {
        if q.d() != self.snap.ds.d() {
            return Err(SkmError::invalid_query(format!(
                "vocabulary does not match the corpus (query d={}, corpus d={})",
                q.d(),
                self.snap.ds.d()
            )));
        }
        match self.route_pruned(s, q, top_p) {
            Ok(out) => Ok(out),
            Err(e) => {
                self.note_fallback(&e);
                Ok(self.route_exact(q, top_p))
            }
        }
    }

    /// The ES-pruned routing path (scratch contents are fully
    /// overwritten up front, so a failed attempt leaves nothing stale
    /// for the next query).
    fn route_pruned(
        &self,
        s: &mut RouteScratch,
        q: &Query,
        top_p: usize,
    ) -> SkmResult<(Vec<(u32, f64)>, OpCounters)> {
        let k = self.snap.k;
        crate::failpoint_res!("router.route", 0u64);
        // Cheap structural self-checks: the kernels' unchecked scatter
        // targets are sized from these, so disagreement means the index
        // no longer matches the snapshot — degrade instead of risking
        // the assert/UB tier.
        if self.snap.means.m.n_rows() != k {
            return Err(SkmError::IndexInconsistent {
                detail: format!(
                    "mean set has {} rows but snapshot K={k}",
                    self.snap.means.m.n_rows()
                ),
            });
        }
        let p = top_p.clamp(1, k);
        let mut counters = OpCounters::new();
        if s.rho.len() != k {
            s.rho.clear();
            s.rho.resize(k, 0.0);
        }
        let t_th = self.params.t_th;
        let v_th = self.params.v_th;
        let ((lts, lus), (hts, hus)) = q.split(t_th);

        // Appendix-A scaling on the fly: u' = u·v_th. The Region-3
        // upper-bound mass is Σ u' over the query's high terms.
        let mut y_base = 0.0;
        for &u in hus {
            y_base += u * v_th;
        }
        s.rho.iter_mut().for_each(|r| *r = y_base);
        let mut mult = 0u64;

        // Gather: Region 1 through the shared dispatch (dense tail rows
        // included), Region 2 through the unrolled kernel. Folded form:
        // after this loop rho[j] upper-bounds the exact cosine.
        for (&t, &u) in lts.iter().zip(lus) {
            mult += self.idx.r1.gather_term(t as usize, u * v_th, &mut s.rho, false);
        }
        for (&t, &u) in hts.iter().zip(hus) {
            let (ids, vals) = self.idx.r2.postings(t as usize);
            mult += ids.len() as u64;
            // SAFETY: Region-2 ids are centroid ids < k == rho.len() by
            // index construction, and pairwise distinct within one
            // term's list (same argument as the assigners'; required by
            // the SIMD gather/scatter backends).
            unsafe { kernel::scatter_add(&mut s.rho, ids, vals, u * v_th) };
        }

        // Seeds: the p largest upper bounds. Score them exactly once —
        // the scores go straight into the final selection — and let τ,
        // their worst exact cosine, lower-bound the true p-th best
        // score, so `ub < τ − ε` prunes.
        s.seeds.clear();
        for (j, &ub) in s.rho.iter().enumerate() {
            push_top(&mut s.seeds, p, ub, j as u32);
        }
        let mut top: Vec<(f64, u32)> = Vec::with_capacity(p + 1);
        let mut tau = f64::INFINITY;
        for &(_, j) in s.seeds.iter() {
            let (mts, mvs) = self.snap.means.m.row(j as usize);
            let (sc, m) = dot_sorted_count(q.ids(), q.vals(), mts, mvs);
            mult += m;
            counters.exact_sims += 1;
            counters.candidates += 1;
            if sc < tau {
                tau = sc;
            }
            push_top(&mut top, p, sc, j);
        }
        let thresh = tau - UB_GUARD;

        // Verify the remaining survivors exactly (seeds are already
        // scored and always pass the threshold — skip them instead of
        // re-scoring). Final selection under the total order matches
        // the brute-force oracle bit for bit: it sees exactly one
        // (score, id) pair per candidate, and push_top's result is
        // insertion-order independent.
        for (j, &ub) in s.rho.iter().enumerate() {
            if ub >= thresh && !s.seeds.iter().any(|&(_, id)| id as usize == j) {
                counters.candidates += 1;
                counters.exact_sims += 1;
                let (mts, mvs) = self.snap.means.m.row(j);
                let (sc, m) = dot_sorted_count(q.ids(), q.vals(), mts, mvs);
                mult += m;
                push_top(&mut top, p, sc, j as u32);
            }
        }
        counters.mult = mult;
        Ok((top.into_iter().map(|(sc, j)| (j, sc)).collect(), counters))
    }

    /// The degradation target: a branch-free brute-force scan — one
    /// exact sparse merge per mean, final top-p under the same total
    /// order. By the module's exactness contract this returns the same
    /// ids and score bits as the pruned path; it touches none of the
    /// structured index, so it serves through index inconsistencies.
    /// Counters reflect the work actually done (all K candidates).
    pub fn route_exact(&self, q: &Query, top_p: usize) -> (Vec<(u32, f64)>, OpCounters) {
        let k = self.snap.k;
        let p = top_p.clamp(1, k);
        let mut counters = OpCounters::new();
        let mut top: Vec<(f64, u32)> = Vec::with_capacity(p + 1);
        for j in 0..k {
            let (mts, mvs) = self.snap.means.m.row(j);
            let (sc, m) = dot_sorted_count(q.ids(), q.vals(), mts, mvs);
            counters.mult += m;
            counters.exact_sims += 1;
            counters.candidates += 1;
            push_top(&mut top, p, sc, j as u32);
        }
        (top.into_iter().map(|(sc, j)| (j, sc)).collect(), counters)
    }

    /// Route, then scan the routed clusters' member documents for the
    /// exact top-`k` nearest documents (same total order; exact over
    /// the routed subset). `top_k == 0` returns routing only. Same
    /// error semantics as [`Router::route`].
    pub fn retrieve(&self, q: &Query, top_p: usize, top_k: usize) -> SkmResult<ServeResult> {
        let mut s = self.checkout_scratch();
        let out = self.retrieve_with(&mut s, q, top_p, top_k);
        self.checkin_scratch(s);
        out
    }

    /// The per-query serving core, against caller-held scratch.
    pub(crate) fn retrieve_with(
        &self,
        s: &mut RouteScratch,
        q: &Query,
        top_p: usize,
        top_k: usize,
    ) -> SkmResult<ServeResult> {
        let (centroids, mut counters) = self.route_with(s, q, top_p)?;
        let mut hits: Vec<(f64, u32)> = Vec::with_capacity(top_k.min(64) + 1);
        for &(c, _) in &centroids {
            for &i in self.snap.members(c as usize) {
                // In-RAM: borrows the CSR. Disk-backed: decodes the
                // row's chunks through the block cache into this
                // scratch. Same bits either way, so the score bits
                // below are identical across the two paths.
                let (ts, vs) = self.snap.row_view(
                    i as usize,
                    &mut s.row_bytes,
                    &mut s.row_ids,
                    &mut s.row_vals,
                );
                let (sc, m) = dot_sorted_count(q.ids(), q.vals(), ts, vs);
                counters.mult += m;
                counters.exact_sims += 1;
                push_top(&mut hits, top_k, sc, i);
            }
        }
        Ok(ServeResult {
            centroids,
            hits: hits.into_iter().map(|(sc, i)| (i, sc)).collect(),
            counters,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_top_orders_and_bounds() {
        let mut top = Vec::new();
        for (s, i) in [(0.5, 3u32), (0.9, 1), (0.5, 2), (0.1, 0), (0.9, 4)] {
            push_top(&mut top, 3, s, i);
        }
        // (score desc, id asc): 0.9@1, 0.9@4, 0.5@2
        assert_eq!(top, vec![(0.9, 1), (0.9, 4), (0.5, 2)]);
        push_top(&mut top, 3, 0.95, 9);
        assert_eq!(top[0], (0.95, 9));
        assert_eq!(top.len(), 3);
        let mut empty = Vec::new();
        push_top(&mut empty, 0, 1.0, 1);
        assert!(empty.is_empty());
    }

    #[test]
    fn dot_count_matches_dot_sorted() {
        let (ta, va) = (vec![0u32, 2, 5], vec![0.5, 0.25, 0.75]);
        let (tb, vb) = (vec![2u32, 5, 7], vec![1.0, 2.0, 4.0]);
        let (s, m) = dot_sorted_count(&ta, &va, &tb, &vb);
        assert_eq!(
            s.to_bits(),
            crate::sparse::dot_sorted(&ta, &va, &tb, &vb).to_bits()
        );
        assert_eq!(m, 2);
        assert_eq!(dot_sorted_count(&[], &[], &tb, &vb), (0.0, 0));
    }
}
