//! Edge-case and failure-injection tests: degenerate inputs, boundary
//! parameter values, and adversarial corpus shapes that the paper's
//! algorithms must survive *exactly* (same solution as MIVI) without
//! panicking.

use skm::algo::{run_clustering, AlgoKind, ClusterConfig};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::index::{update_means, EsIndex, InvIndex, MeanSet};
use skm::sparse::{build_dataset, CsrMatrix, Dataset};

fn run_all(ds: &Dataset, cfg: &ClusterConfig) {
    let base = run_clustering(AlgoKind::Mivi, ds, cfg);
    for &kind in AlgoKind::all() {
        if kind == AlgoKind::Mivi {
            continue;
        }
        let out = run_clustering(kind, ds, cfg);
        assert_eq!(
            out.assign,
            base.assign,
            "{} diverged on edge case",
            kind.name()
        );
    }
}

/// K = 1: everything collapses into one cluster after one iteration.
#[test]
fn single_cluster() {
    let c = generate(&tiny(1000));
    let ds = build_dataset("t", c.n_terms, &c.docs);
    let cfg = ClusterConfig {
        k: 1,
        seed: 1,
        ..Default::default()
    };
    run_all(&ds, &cfg);
    let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    assert!(out.assign.iter().all(|&a| a == 0));
    assert!(out.converged);
}

/// K = N: every document is its own cluster seed; heavy tie territory.
#[test]
fn k_equals_n_over_2() {
    let c = generate(&CorpusSpec {
        n_docs: 120,
        ..tiny(1001)
    });
    let ds = build_dataset("t", c.n_terms, &c.docs);
    let cfg = ClusterConfig {
        k: 60,
        seed: 2,
        ..Default::default()
    };
    run_all(&ds, &cfg);
}

/// Duplicate documents: exact ties everywhere; deterministic tie-break
/// must keep all algorithms aligned.
#[test]
fn duplicate_documents() {
    let c = generate(&CorpusSpec {
        n_docs: 80,
        ..tiny(1002)
    });
    let mut docs = c.docs.clone();
    let dups: Vec<_> = docs.iter().take(40).cloned().collect();
    docs.extend(dups); // 40 exact duplicates
    let ds = build_dataset("t", c.n_terms, &docs);
    let cfg = ClusterConfig {
        k: 8,
        seed: 3,
        ..Default::default()
    };
    run_all(&ds, &cfg);
}

/// Single-term documents: extreme sparsity (nt = 1), many zero
/// similarities.
#[test]
fn single_term_documents() {
    let mut docs = Vec::new();
    for i in 0..200u32 {
        docs.push(vec![(i % 23, 1 + i % 5)]);
    }
    let ds = build_dataset("t", 23, &docs);
    let cfg = ClusterConfig {
        k: 6,
        seed: 4,
        ..Default::default()
    };
    run_all(&ds, &cfg);
}

/// A corpus where one term appears in every document (idf = 0 weight)
/// plus near-empty docs.
#[test]
fn ubiquitous_term_and_tiny_docs() {
    let mut docs = Vec::new();
    for i in 0..150u32 {
        let mut d = vec![(0u32, 3u32)]; // ubiquitous term
        if i % 3 != 0 {
            d.push((1 + (i % 17), 2));
        }
        if i % 5 == 0 {
            d.push((20 + (i % 7), 1));
        }
        docs.push(d);
    }
    let ds = build_dataset("t", 40, &docs);
    // Docs consisting ONLY of the idf-0 term have zero-norm vectors —
    // the pipeline must not produce NaNs and clustering must agree.
    let cfg = ClusterConfig {
        k: 5,
        seed: 5,
        ..Default::default()
    };
    let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);
    assert!(base.objective.is_finite());
    for kind in [AlgoKind::EsIcp, AlgoKind::CsIcp, AlgoKind::TaIcp, AlgoKind::Icp] {
        let out = run_clustering(kind, &ds, &cfg);
        assert_eq!(out.assign, base.assign, "{}", kind.name());
        assert!(out.objective.is_finite());
    }
}

/// max_iters = 1: no convergence, but valid partial output.
#[test]
fn iteration_cap() {
    let c = generate(&tiny(1003));
    let ds = build_dataset("t", c.n_terms, &c.docs);
    let cfg = ClusterConfig {
        k: 8,
        seed: 6,
        max_iters: 1,
        ..Default::default()
    };
    let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    assert_eq!(out.iterations(), 1);
    assert!(!out.converged);
}

/// Extreme structural parameters on the EsIndex must partition cleanly.
#[test]
fn es_index_parameter_boundaries() {
    let c = generate(&tiny(1004));
    let ds = build_dataset("t", c.n_terms, &c.docs);
    let cfg = ClusterConfig {
        k: 6,
        seed: 7,
        max_iters: 2,
        ..Default::default()
    };
    let out = run_clustering(AlgoKind::Mivi, &ds, &cfg);
    let upd = update_means(&ds, &out.assign, 6, None, None);
    let d = ds.d();
    for (t_th, v_th) in [
        (0usize, 1e-9f64), // everything Region 2 (all values ≥ v_th)
        (0, 2.0),          // v_th above all values: everything Region 3
        (d, 1.0),          // everything Region 1
        (d - 1, 0.5),
        (1, 0.5),
    ] {
        let idx = EsIndex::build(&upd.means, t_th, v_th);
        // Every mean entry is represented exactly once (r1 + r2 + the
        // non-trivial deficit cells of the partial index).
        let r1_nnz: usize = (0..t_th).map(|s| idx.r1.mf(s)).sum();
        let r2_nnz = idx.r2.nnz();
        let partial_nnz: usize = (t_th..d)
            .map(|s| {
                idx.partial
                    .row(s)
                    .iter()
                    .filter(|&&w| w > 0.0 && w < 1.0)
                    .count()
            })
            .sum();
        assert_eq!(
            r1_nnz + r2_nnz + partial_nnz,
            upd.means.m.nnz(),
            "partition broken at t_th={t_th} v_th={v_th}"
        );
    }
}

/// InvIndex with no moving centroids and all moving centroids.
#[test]
fn inv_index_moving_block_extremes() {
    let c = generate(&tiny(1005));
    let ds = build_dataset("t", c.n_terms, &c.docs);
    let assign: Vec<u32> = (0..ds.n() as u32).map(|i| i % 4).collect();
    let upd = update_means(&ds, &assign, 4, None, None);
    let mut means: MeanSet = upd.means;

    means.moved = vec![false; 4];
    let idx = InvIndex::build(&means, ds.d());
    assert!(idx.moving_ids.is_empty());
    for s in 0..ds.d() {
        assert_eq!(idx.mfm[s], 0);
        let (ids, _) = idx.postings_moving(s);
        assert!(ids.is_empty());
    }

    means.moved = vec![true; 4];
    let idx = InvIndex::build(&means, ds.d());
    assert_eq!(idx.moving_ids, vec![0, 1, 2, 3]);
    for s in 0..ds.d() {
        assert_eq!(idx.mfm[s] as usize, idx.mf(s));
    }
}

/// CSR with explicitly zero values (idf-0 terms) keeps algorithms
/// consistent: a zero value participates in postings but adds nothing.
#[test]
fn explicit_zero_values() {
    let m = CsrMatrix::from_rows(4, &[vec![(0, 0.0), (1, 1.0)], vec![(1, 1.0)]]);
    assert_eq!(m.nnz(), 3);
    assert_eq!(m.row_dot(0, 1), 1.0);
    let df = m.column_df();
    assert_eq!(df[0], 1); // the zero entry still counts structurally
}

/// Seeds differing only in the corpus (not the clustering seed) give
/// different data but each run remains internally consistent.
#[test]
fn cross_corpus_stability() {
    for cs in [2000u64, 2001, 2002] {
        let c = generate(&CorpusSpec {
            n_docs: 250,
            ..tiny(cs)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 7,
            seed: 1,
            ..Default::default()
        };
        let a = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
        let b = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
        assert_eq!(a.assign, b.assign, "nondeterminism at corpus seed {cs}");
        assert_eq!(a.objective, b.objective);
    }
}

/// NYT-style long documents (large nt) with a small vocabulary stress
/// the Region-2 paths (most terms above t_th).
#[test]
fn long_documents_small_vocab() {
    let c = generate(&CorpusSpec {
        n_docs: 150,
        n_terms: 300,
        mean_doc_len: 200.0,
        ..tiny(1006)
    });
    let ds = build_dataset("t", c.n_terms, &c.docs);
    assert!(ds.avg_terms() > 50.0);
    let cfg = ClusterConfig {
        k: 6,
        seed: 8,
        ..Default::default()
    };
    run_all(&ds, &cfg);
}
