//! Property-based tests (hand-rolled generators — no proptest crate in
//! the offline environment): randomized sweeps asserting structural
//! invariants of the substrate and the coordinator state machine.

use skm::algo::{run_clustering, seed_means, AlgoKind, ClusterConfig};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::index::{membership_changes, update_means, InvIndex};
use skm::metrics::{entropy, mutual_information, nmi};
use skm::sparse::{build_dataset, build_dataset_bm25, dot_sorted, Bm25Params, CsrMatrix};
use skm::util::rng::Pcg32;
use skm::util::stats::{fast_exp, quantile_sorted};

/// Random sparse rows for CSR property tests.
fn random_rows(rng: &mut Pcg32, n: usize, d: usize, max_nnz: usize) -> Vec<Vec<(u32, f64)>> {
    (0..n)
        .map(|_| {
            let nnz = rng.gen_range(max_nnz as u32 + 1) as usize;
            let cols = rng.sample_distinct(d, nnz.min(d));
            cols.into_iter()
                .map(|c| (c as u32, rng.next_f64() * 10.0 - 5.0))
                .collect()
        })
        .collect()
}

#[test]
fn prop_csr_roundtrip_row_access() {
    let mut rng = Pcg32::new(42);
    for trial in 0..30 {
        let d = 5 + rng.gen_range(100) as usize;
        let rows = random_rows(&mut rng, 20, d, 12);
        let m = CsrMatrix::from_rows(d, &rows);
        for (i, row) in rows.iter().enumerate() {
            let dense = m.row_dense(i);
            let mut expect = vec![0.0; d];
            for &(c, v) in row {
                expect[c as usize] += v;
            }
            for c in 0..d {
                assert!(
                    (dense[c] - expect[c]).abs() < 1e-12,
                    "trial {trial} row {i} col {c}"
                );
            }
        }
    }
}

/// CSR round trip: rows read back out of a matrix rebuild the identical
/// matrix (structure and value bits), including empty rows and rows
/// that arrived unsorted / with duplicate columns.
#[test]
fn prop_csr_rebuild_from_rows_is_identity() {
    let mut rng = Pcg32::new(0xc5a_0071);
    for trial in 0..25 {
        let d = 4 + rng.gen_range(60) as usize;
        let mut rows = random_rows(&mut rng, 15, d, 10);
        rows.push(Vec::new()); // always include an empty row
        let m = CsrMatrix::from_rows(d, &rows);
        let readback: Vec<Vec<(u32, f64)>> = (0..m.n_rows())
            .map(|i| {
                let (ts, vs) = m.row(i);
                ts.iter().cloned().zip(vs.iter().cloned()).collect()
            })
            .collect();
        let rebuilt = CsrMatrix::from_rows(d, &readback);
        assert_eq!(m, rebuilt, "trial {trial}: CSR round trip not identity");
        assert_eq!(m.nnz(), rebuilt.nnz());
    }
}

/// Random bag-of-words corpora: tf-idf weighting invariants — every
/// stored weight is non-negative (idf = ln(N/df) ≥ 0, tf > 0), every
/// row is unit-L2 (or exactly zero when all its terms are ubiquitous),
/// and the relabeled document frequencies ascend.
#[test]
fn prop_tfidf_rows_nonnegative_and_unit_norm() {
    let mut rng = Pcg32::new(0x7f1d_f01d);
    for trial in 0..20 {
        let n_terms = 10 + rng.gen_range(40) as usize;
        let n_docs = 20 + rng.gen_range(60) as usize;
        let docs: Vec<Vec<(u32, u32)>> = (0..n_docs)
            .map(|_| {
                let nnz = 1 + rng.gen_range(8) as usize;
                rng.sample_distinct(n_terms, nnz.min(n_terms))
                    .into_iter()
                    .map(|t| (t as u32, 1 + rng.gen_range(9)))
                    .collect()
            })
            .collect();
        let ds = build_dataset("t", n_terms, &docs);
        assert!(ds.df.windows(2).all(|w| w[0] <= w[1]), "trial {trial}: df order");
        for i in 0..ds.n() {
            let (_, vs) = ds.x.row(i);
            assert!(
                vs.iter().all(|&v| v >= 0.0 && v.is_finite()),
                "trial {trial} row {i}: negative/non-finite tf-idf weight"
            );
            let norm = ds.x.row_norm(i);
            assert!(
                (norm - 1.0).abs() < 1e-9 || norm == 0.0,
                "trial {trial} row {i}: norm {norm}"
            );
        }
    }
}

/// Same invariants for the BM25 weighting (strictly positive weights —
/// the +1 idf variant never vanishes), plus agreement of the df
/// relabeling with tf-idf's (both sort by (df, original id)).
#[test]
fn prop_bm25_rows_positive_and_unit_norm() {
    let mut rng = Pcg32::new(0xb2_5b25);
    for trial in 0..15 {
        let n_terms = 12 + rng.gen_range(30) as usize;
        let n_docs = 25 + rng.gen_range(50) as usize;
        let docs: Vec<Vec<(u32, u32)>> = (0..n_docs)
            .map(|_| {
                let nnz = 1 + rng.gen_range(6) as usize;
                rng.sample_distinct(n_terms, nnz.min(n_terms))
                    .into_iter()
                    .map(|t| (t as u32, 1 + rng.gen_range(7)))
                    .collect()
            })
            .collect();
        let bm = build_dataset_bm25("t", n_terms, &docs, Bm25Params::default());
        let tf = build_dataset("t", n_terms, &docs);
        assert!(bm.df.windows(2).all(|w| w[0] <= w[1]), "trial {trial}");
        assert_eq!(bm.df, tf.df, "trial {trial}: df relabeling disagrees");
        assert_eq!(bm.orig_term, tf.orig_term, "trial {trial}");
        for i in 0..bm.n() {
            let (_, vs) = bm.x.row(i);
            assert!(
                vs.iter().all(|&v| v > 0.0 && v.is_finite()),
                "trial {trial} row {i}: nonpositive BM25 weight"
            );
            let norm = bm.x.row_norm(i);
            assert!((norm - 1.0).abs() < 1e-9, "trial {trial} row {i}: {norm}");
        }
    }
}

/// Feature-extraction edge cases: empty documents produce zero rows (no
/// NaNs anywhere downstream of normalization), single-term documents
/// normalize to a unit spike, and duplicate term entries within one
/// document merge to the summed count's weight.
#[test]
fn prop_build_dataset_edge_rows() {
    // Empty + single-term rows.
    let docs = vec![
        vec![],                    // empty document
        vec![(3u32, 5u32)],        // single term
        vec![(1, 2), (3, 1)],      // keeps term 3 from having df == N
        vec![(1, 1)],
    ];
    let ds = build_dataset("edge", 6, &docs);
    let (ts0, vs0) = ds.x.row(0);
    assert!(ts0.is_empty() && vs0.is_empty(), "empty doc must give an empty row");
    assert_eq!(ds.x.row_norm(0), 0.0);
    let (ts1, vs1) = ds.x.row(1);
    assert_eq!(ts1.len(), 1, "single-term doc keeps exactly one entry");
    assert!((vs1[0] - 1.0).abs() < 1e-12, "unit spike after normalization");
    for i in 0..ds.n() {
        let (_, vs) = ds.x.row(i);
        assert!(vs.iter().all(|v| v.is_finite()));
    }

    // Duplicate term ids within a document sum their counts' weights:
    // [(t,2),(t,3)] must weigh like [(t,5)] (same idf, summed tf).
    let dup = vec![vec![(0u32, 2u32), (0, 3), (2, 1)], vec![(1, 1), (2, 2)]];
    let merged = vec![vec![(0u32, 5u32), (2, 1)], vec![(1, 1), (2, 2)]];
    let a = build_dataset("dup", 4, &dup);
    let b = build_dataset("merged", 4, &merged);
    assert_eq!(a.df, b.df, "df must dedup within a document");
    for i in 0..a.n() {
        let (ta, va) = a.x.row(i);
        let (tb, vb) = b.x.row(i);
        assert_eq!(ta, tb, "row {i}: structure");
        for (x, y) in va.iter().zip(vb) {
            assert!((x - y).abs() < 1e-12, "row {i}: {x} vs {y}");
        }
    }
}

#[test]
fn prop_dot_sorted_matches_dense_dot() {
    let mut rng = Pcg32::new(7);
    for _ in 0..50 {
        let d = 10 + rng.gen_range(80) as usize;
        let rows = random_rows(&mut rng, 2, d, 15);
        let m = CsrMatrix::from_rows(d, &rows);
        let (ta, va) = m.row(0);
        let (tb, vb) = m.row(1);
        let sparse = dot_sorted(ta, va, tb, vb);
        let dense: f64 = m
            .row_dense(0)
            .iter()
            .zip(m.row_dense(1).iter())
            .map(|(a, b)| a * b)
            .sum();
        assert!((sparse - dense).abs() < 1e-9);
    }
}

#[test]
fn prop_permute_columns_preserves_dots() {
    let mut rng = Pcg32::new(13);
    for _ in 0..20 {
        let d = 8 + rng.gen_range(40) as usize;
        let rows = random_rows(&mut rng, 6, d, 10);
        let m = CsrMatrix::from_rows(d, &rows);
        let mut perm: Vec<u32> = (0..d as u32).collect();
        rng.shuffle(&mut perm);
        let mut p = m.clone();
        p.permute_columns(&perm);
        for i in 0..6 {
            for j in 0..6 {
                assert!(
                    (m.row_dot(i, j) - p.row_dot(i, j)).abs() < 1e-9,
                    "dot not invariant under column permutation"
                );
            }
        }
    }
}

#[test]
fn prop_inverted_index_is_transpose() {
    // For random mean sets: reading the index column-wise reconstructs
    // exactly the mean matrix.
    let mut rng = Pcg32::new(99);
    for _ in 0..10 {
        let c = generate(&CorpusSpec {
            n_docs: 100 + rng.gen_range(150) as usize,
            ..tiny(rng.next_u64())
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let k = 3 + rng.gen_range(6) as usize;
        let assign: Vec<u32> = (0..ds.n()).map(|_| rng.gen_range(k as u32)).collect();
        let upd = update_means(&ds, &assign, k, None, None);
        let idx = InvIndex::build(&upd.means, ds.d());
        let mut total = 0usize;
        for s in 0..ds.d() {
            let (ids, vals) = idx.postings(s);
            for (&j, &v) in ids.iter().zip(vals) {
                assert_eq!(upd.means.m.row_dense(j as usize)[s], v);
                total += 1;
            }
        }
        assert_eq!(total, upd.means.m.nnz());
    }
}

#[test]
fn prop_membership_changes_symmetric_difference() {
    let mut rng = Pcg32::new(5);
    for _ in 0..30 {
        let n = 50;
        let k = 6;
        let a: Vec<u32> = (0..n).map(|_| rng.gen_range(k)).collect();
        let mut b = a.clone();
        // Flip a random subset.
        let flips = rng.gen_range(10) as usize;
        for _ in 0..flips {
            let i = rng.gen_range(n) as usize;
            b[i] = rng.gen_range(k);
        }
        let ch = membership_changes(&a, &b, k as usize);
        for j in 0..k as usize {
            let members_a: Vec<usize> =
                (0..n as usize).filter(|&i| a[i] == j as u32).collect();
            let members_b: Vec<usize> =
                (0..n as usize).filter(|&i| b[i] == j as u32).collect();
            assert_eq!(
                ch[j],
                members_a != members_b,
                "changed flag wrong for cluster {j}"
            );
        }
    }
}

#[test]
fn prop_update_means_objective_equals_rho_sum() {
    let mut rng = Pcg32::new(21);
    for _ in 0..8 {
        let c = generate(&CorpusSpec {
            n_docs: 120,
            ..tiny(rng.next_u64())
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let k = 5;
        let assign: Vec<u32> = (0..ds.n()).map(|_| rng.gen_range(k)).collect();
        let upd = update_means(&ds, &assign, k as usize, None, None);
        let sum: f64 = upd.rho.iter().sum();
        assert!((upd.objective - sum).abs() < 1e-9);
        // ρ is a cosine similarity: bounded by 1 + ε.
        assert!(upd.rho.iter().all(|&r| (-1e-9..=1.0 + 1e-9).contains(&r)));
    }
}

#[test]
fn prop_seeding_rows_are_dataset_rows() {
    let c = generate(&tiny(77));
    let ds = build_dataset("t", c.n_terms, &c.docs);
    for seed in 0..5u64 {
        let means = seed_means(&ds, 9, seed);
        for j in 0..9 {
            let (ts, vs) = means.m.row(j);
            // Each seed mean equals some dataset row exactly.
            let found = (0..ds.n()).any(|i| ds.x.row(i) == (ts, vs));
            assert!(found, "seed mean {j} is not a dataset row");
        }
    }
}

#[test]
fn prop_nmi_information_inequalities() {
    // I(X;Y) <= min(H(X), H(Y)); NMI in [0, 1]; NMI(x,x) = 1.
    let mut rng = Pcg32::new(31);
    for _ in 0..40 {
        let n = 200;
        let ka = 1 + rng.gen_range(8);
        let kb = 1 + rng.gen_range(8);
        let a: Vec<u32> = (0..n).map(|_| rng.gen_range(ka)).collect();
        let b: Vec<u32> = (0..n).map(|_| rng.gen_range(kb)).collect();
        let i = mutual_information(&a, &b);
        assert!(i >= -1e-12);
        assert!(i <= entropy(&a).min(entropy(&b)) + 1e-9);
        let s = nmi(&a, &b);
        assert!((0.0..=1.0).contains(&s));
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-9 || entropy(&a) == 0.0);
    }
}

#[test]
fn prop_fast_exp_bounded_error_random() {
    let mut rng = Pcg32::new(55);
    for _ in 0..10_000 {
        let x = rng.next_f64() * 80.0 - 40.0;
        let rel = (fast_exp(x) - x.exp()).abs() / x.exp();
        assert!(rel < 1e-3, "x={x} rel={rel}");
    }
}

#[test]
fn prop_quantile_monotone() {
    let mut rng = Pcg32::new(61);
    for _ in 0..20 {
        let mut xs: Vec<f64> = (0..100).map(|_| rng.next_f64() * 100.0).collect();
        xs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = f64::NEG_INFINITY;
        for q in 0..=20 {
            let v = quantile_sorted(&xs, q as f64 / 20.0);
            assert!(v >= prev - 1e-12);
            prev = v;
        }
        assert_eq!(quantile_sorted(&xs, 0.0), xs[0]);
        assert_eq!(quantile_sorted(&xs, 1.0), xs[99]);
    }
}

/// Coordinator state-machine invariant: per-iteration change counts are
/// positive until the final iteration, where they are zero; CPR stays in
/// [0, 1]; memory reports are stable.
#[test]
fn prop_coordinator_iteration_state() {
    let mut rng = Pcg32::new(71);
    for _ in 0..4 {
        let c = generate(&CorpusSpec {
            n_docs: 200 + rng.gen_range(200) as usize,
            ..tiny(rng.next_u64())
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 6 + rng.gen_range(6) as usize,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
        assert!(out.converged);
        let logs = &out.logs;
        for (idx, l) in logs.iter().enumerate() {
            assert!((0.0..=1.0 + 1e-12).contains(&l.cpr), "CPR out of range");
            assert!(l.mem_bytes > 0);
            if idx + 1 < logs.len() {
                assert!(l.changes > 0, "premature zero-change iteration");
            } else {
                assert_eq!(l.changes, 0, "final iteration must be stable");
            }
        }
    }
}
