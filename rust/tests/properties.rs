//! Property-based tests (hand-rolled generators — no proptest crate in
//! the offline environment): randomized sweeps asserting structural
//! invariants of the substrate and the coordinator state machine.

use skm::algo::{run_clustering, seed_means, AlgoKind, ClusterConfig};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::index::{membership_changes, update_means, InvIndex};
use skm::metrics::{entropy, mutual_information, nmi};
use skm::sparse::{build_dataset, dot_sorted, CsrMatrix};
use skm::util::rng::Pcg32;
use skm::util::stats::{fast_exp, quantile_sorted};

/// Random sparse rows for CSR property tests.
fn random_rows(rng: &mut Pcg32, n: usize, d: usize, max_nnz: usize) -> Vec<Vec<(u32, f64)>> {
    (0..n)
        .map(|_| {
            let nnz = rng.gen_range(max_nnz as u32 + 1) as usize;
            let cols = rng.sample_distinct(d, nnz.min(d));
            cols.into_iter()
                .map(|c| (c as u32, rng.next_f64() * 10.0 - 5.0))
                .collect()
        })
        .collect()
}

#[test]
fn prop_csr_roundtrip_row_access() {
    let mut rng = Pcg32::new(42);
    for trial in 0..30 {
        let d = 5 + rng.gen_range(100) as usize;
        let rows = random_rows(&mut rng, 20, d, 12);
        let m = CsrMatrix::from_rows(d, &rows);
        for (i, row) in rows.iter().enumerate() {
            let dense = m.row_dense(i);
            let mut expect = vec![0.0; d];
            for &(c, v) in row {
                expect[c as usize] += v;
            }
            for c in 0..d {
                assert!(
                    (dense[c] - expect[c]).abs() < 1e-12,
                    "trial {trial} row {i} col {c}"
                );
            }
        }
    }
}

#[test]
fn prop_dot_sorted_matches_dense_dot() {
    let mut rng = Pcg32::new(7);
    for _ in 0..50 {
        let d = 10 + rng.gen_range(80) as usize;
        let rows = random_rows(&mut rng, 2, d, 15);
        let m = CsrMatrix::from_rows(d, &rows);
        let (ta, va) = m.row(0);
        let (tb, vb) = m.row(1);
        let sparse = dot_sorted(ta, va, tb, vb);
        let dense: f64 = m
            .row_dense(0)
            .iter()
            .zip(m.row_dense(1).iter())
            .map(|(a, b)| a * b)
            .sum();
        assert!((sparse - dense).abs() < 1e-9);
    }
}

#[test]
fn prop_permute_columns_preserves_dots() {
    let mut rng = Pcg32::new(13);
    for _ in 0..20 {
        let d = 8 + rng.gen_range(40) as usize;
        let rows = random_rows(&mut rng, 6, d, 10);
        let m = CsrMatrix::from_rows(d, &rows);
        let mut perm: Vec<u32> = (0..d as u32).collect();
        rng.shuffle(&mut perm);
        let mut p = m.clone();
        p.permute_columns(&perm);
        for i in 0..6 {
            for j in 0..6 {
                assert!(
                    (m.row_dot(i, j) - p.row_dot(i, j)).abs() < 1e-9,
                    "dot not invariant under column permutation"
                );
            }
        }
    }
}

#[test]
fn prop_inverted_index_is_transpose() {
    // For random mean sets: reading the index column-wise reconstructs
    // exactly the mean matrix.
    let mut rng = Pcg32::new(99);
    for _ in 0..10 {
        let c = generate(&CorpusSpec {
            n_docs: 100 + rng.gen_range(150) as usize,
            ..tiny(rng.next_u64())
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let k = 3 + rng.gen_range(6) as usize;
        let assign: Vec<u32> = (0..ds.n()).map(|_| rng.gen_range(k as u32)).collect();
        let upd = update_means(&ds, &assign, k, None, None);
        let idx = InvIndex::build(&upd.means, ds.d());
        let mut total = 0usize;
        for s in 0..ds.d() {
            let (ids, vals) = idx.postings(s);
            for (&j, &v) in ids.iter().zip(vals) {
                assert_eq!(upd.means.m.row_dense(j as usize)[s], v);
                total += 1;
            }
        }
        assert_eq!(total, upd.means.m.nnz());
    }
}

#[test]
fn prop_membership_changes_symmetric_difference() {
    let mut rng = Pcg32::new(5);
    for _ in 0..30 {
        let n = 50;
        let k = 6;
        let a: Vec<u32> = (0..n).map(|_| rng.gen_range(k)).collect();
        let mut b = a.clone();
        // Flip a random subset.
        let flips = rng.gen_range(10) as usize;
        for _ in 0..flips {
            let i = rng.gen_range(n) as usize;
            b[i] = rng.gen_range(k);
        }
        let ch = membership_changes(&a, &b, k as usize);
        for j in 0..k as usize {
            let members_a: Vec<usize> =
                (0..n as usize).filter(|&i| a[i] == j as u32).collect();
            let members_b: Vec<usize> =
                (0..n as usize).filter(|&i| b[i] == j as u32).collect();
            assert_eq!(
                ch[j],
                members_a != members_b,
                "changed flag wrong for cluster {j}"
            );
        }
    }
}

#[test]
fn prop_update_means_objective_equals_rho_sum() {
    let mut rng = Pcg32::new(21);
    for _ in 0..8 {
        let c = generate(&CorpusSpec {
            n_docs: 120,
            ..tiny(rng.next_u64())
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let k = 5;
        let assign: Vec<u32> = (0..ds.n()).map(|_| rng.gen_range(k)).collect();
        let upd = update_means(&ds, &assign, k as usize, None, None);
        let sum: f64 = upd.rho.iter().sum();
        assert!((upd.objective - sum).abs() < 1e-9);
        // ρ is a cosine similarity: bounded by 1 + ε.
        assert!(upd.rho.iter().all(|&r| (-1e-9..=1.0 + 1e-9).contains(&r)));
    }
}

#[test]
fn prop_seeding_rows_are_dataset_rows() {
    let c = generate(&tiny(77));
    let ds = build_dataset("t", c.n_terms, &c.docs);
    for seed in 0..5u64 {
        let means = seed_means(&ds, 9, seed);
        for j in 0..9 {
            let (ts, vs) = means.m.row(j);
            // Each seed mean equals some dataset row exactly.
            let found = (0..ds.n()).any(|i| ds.x.row(i) == (ts, vs));
            assert!(found, "seed mean {j} is not a dataset row");
        }
    }
}

#[test]
fn prop_nmi_information_inequalities() {
    // I(X;Y) <= min(H(X), H(Y)); NMI in [0, 1]; NMI(x,x) = 1.
    let mut rng = Pcg32::new(31);
    for _ in 0..40 {
        let n = 200;
        let ka = 1 + rng.gen_range(8);
        let kb = 1 + rng.gen_range(8);
        let a: Vec<u32> = (0..n).map(|_| rng.gen_range(ka)).collect();
        let b: Vec<u32> = (0..n).map(|_| rng.gen_range(kb)).collect();
        let i = mutual_information(&a, &b);
        assert!(i >= -1e-12);
        assert!(i <= entropy(&a).min(entropy(&b)) + 1e-9);
        let s = nmi(&a, &b);
        assert!((0.0..=1.0).contains(&s));
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-9 || entropy(&a) == 0.0);
    }
}

#[test]
fn prop_fast_exp_bounded_error_random() {
    let mut rng = Pcg32::new(55);
    for _ in 0..10_000 {
        let x = rng.next_f64() * 80.0 - 40.0;
        let rel = (fast_exp(x) - x.exp()).abs() / x.exp();
        assert!(rel < 1e-3, "x={x} rel={rel}");
    }
}

#[test]
fn prop_quantile_monotone() {
    let mut rng = Pcg32::new(61);
    for _ in 0..20 {
        let mut xs: Vec<f64> = (0..100).map(|_| rng.next_f64() * 100.0).collect();
        xs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = f64::NEG_INFINITY;
        for q in 0..=20 {
            let v = quantile_sorted(&xs, q as f64 / 20.0);
            assert!(v >= prev - 1e-12);
            prev = v;
        }
        assert_eq!(quantile_sorted(&xs, 0.0), xs[0]);
        assert_eq!(quantile_sorted(&xs, 1.0), xs[99]);
    }
}

/// Coordinator state-machine invariant: per-iteration change counts are
/// positive until the final iteration, where they are zero; CPR stays in
/// [0, 1]; memory reports are stable.
#[test]
fn prop_coordinator_iteration_state() {
    let mut rng = Pcg32::new(71);
    for _ in 0..4 {
        let c = generate(&CorpusSpec {
            n_docs: 200 + rng.gen_range(200) as usize,
            ..tiny(rng.next_u64())
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 6 + rng.gen_range(6) as usize,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
        assert!(out.converged);
        let logs = &out.logs;
        for (idx, l) in logs.iter().enumerate() {
            assert!((0.0..=1.0 + 1e-12).contains(&l.cpr), "CPR out of range");
            assert!(l.mem_bytes > 0);
            if idx + 1 < logs.len() {
                assert!(l.changes > 0, "premature zero-change iteration");
            } else {
                assert_eq!(l.changes, 0, "final iteration must be stable");
            }
        }
    }
}
