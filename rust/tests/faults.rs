//! Fault-injection suite (ISSUE §Robustness tentpole): drives the
//! compile-time-gated failpoint harness (`util::failpoint`, cargo
//! feature `failpoints`) through every containment boundary and proves
//! the blast radius of an injected fault:
//!
//! * a panicking query fails **only its own slot** in `serve_batch` —
//!   across threads ∈ {1, 2, 4, 7} every other slot stays bit-identical
//!   to the clean run, and the scratch pool is reusable afterwards;
//! * a panicking shard turns `try_run_clustering_with` into a typed
//!   [`SkmError::WorkerPanic`] naming the shard, never a process abort,
//!   and a clean rerun on the same config is bit-identical to serial;
//! * loader failpoints surface as typed [`SkmError::FaultInjected`]
//!   mid-parse; estimation/routing failpoints degrade the router to
//!   exact parameters / the exact scan with results unchanged;
//! * `delay` actions perturb timing only — results stay bit-identical.
//!
//! The failpoint registry is process-global, so every test serializes
//! on one mutex and clears the registry on entry and exit. Run with
//! `cargo test --features failpoints --test faults`; without the
//! feature the whole suite compiles to a single no-op smoke test (the
//! determinism suites then prove the disabled harness changes nothing).

#![cfg_attr(not(feature = "failpoints"), allow(unused_imports, dead_code))]

use skm::algo::{try_run_clustering_with, AlgoKind, ClusterConfig, ParConfig};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::error::SkmError;
use skm::serve::{serve_batch, ClusteredCorpus, Query, Router, RouterParams, ServeResult};
use skm::sparse::build_dataset;

fn dataset(n_docs: usize, seed: u64) -> skm::sparse::Dataset {
    let c = generate(&CorpusSpec {
        n_docs,
        ..tiny(seed)
    });
    build_dataset("faults", c.n_terms, &c.docs)
}

fn snapshot(n_docs: usize, corpus_seed: u64, k: usize) -> ClusteredCorpus {
    let ds = dataset(n_docs, corpus_seed);
    let cfg = ClusterConfig {
        k,
        seed: 3,
        ..Default::default()
    };
    let out = skm::algo::run_clustering_with(AlgoKind::Mivi, &ds, &cfg, &ParConfig::serial());
    ClusteredCorpus::from_output(ds, &out, k)
}

/// Bit-compare two serving results (ids, score bits, counters).
fn assert_result_eq(a: &ServeResult, b: &ServeResult, tag: &str) {
    assert_eq!(a.centroids.len(), b.centroids.len(), "{tag}");
    for (x, y) in a.centroids.iter().zip(&b.centroids) {
        assert_eq!(x.0, y.0, "{tag}: centroid id");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{tag}: centroid score bits");
    }
    assert_eq!(a.hits.len(), b.hits.len(), "{tag}");
    for (x, y) in a.hits.iter().zip(&b.hits) {
        assert_eq!(x.0, y.0, "{tag}: hit id");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{tag}: hit score bits");
    }
    assert_eq!(a.counters, b.counters, "{tag}: counters");
}

#[cfg(feature = "failpoints")]
mod injected {
    use super::*;
    use skm::util::failpoint::{clear_all, set};
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// The registry is process-global; tests must not interleave.
    fn serialize() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        let guard = LOCK
            .get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        clear_all();
        guard
    }

    /// Clears the registry when a test exits, pass or fail.
    struct Cleanup;
    impl Drop for Cleanup {
        fn drop(&mut self) {
            clear_all();
        }
    }

    const SAMPLE: &str = "3\n5\n6\n1 1 2\n1 3 1\n2 2 4\n2 5 1\n3 1 1\n3 4 2\n";

    /// Tentpole proof: `serve.query` panics at one global query index;
    /// for threads ∈ {1, 2, 4, 7} exactly that slot errors and every
    /// other slot is bit-identical to the clean serial baseline. A
    /// clean batch afterwards is also bit-identical — the scratch pool
    /// survives the unwinding holder (non-poisoning locks).
    #[test]
    fn serve_query_panic_fails_only_its_slot() {
        let _g = serialize();
        let _c = Cleanup;
        let snap = snapshot(300, 0x91, 8);
        let router = Router::new(&snap, RouterParams::exact()).unwrap();
        let queries: Vec<Query> = (0..13).map(|i| Query::from_row(&snap.ds, i * 7)).collect();
        let (top_p, top_k) = (3usize, 4usize);
        let (clean, clean_total) =
            serve_batch(&router, &queries, top_p, top_k, &ParConfig::serial());

        let victim = 5usize;
        set("serve.query", &format!("panic@{victim}")).unwrap();
        for threads in [1usize, 2, 4, 7] {
            let par = ParConfig { threads, shard: 3 };
            let (got, _) = serve_batch(&router, &queries, top_p, top_k, &par);
            assert_eq!(got.len(), queries.len());
            for (qi, r) in got.iter().enumerate() {
                let tag = format!("threads={threads} query={qi}");
                if qi == victim {
                    match r {
                        Err(SkmError::WorkerPanic { site, detail }) => {
                            assert_eq!(site, "serve.query", "{tag}");
                            assert!(detail.contains("injected panic"), "{tag}: {detail}");
                        }
                        other => panic!("{tag}: expected WorkerPanic, got {other:?}"),
                    }
                } else {
                    assert_result_eq(
                        r.as_ref().unwrap(),
                        clean[qi].as_ref().unwrap(),
                        &tag,
                    );
                }
            }
        }

        // Containment leaves no residue: with the failpoint cleared the
        // same router and pool serve a bit-identical clean batch.
        clear_all();
        let par = ParConfig { threads: 4, shard: 3 };
        let (after, after_total) = serve_batch(&router, &queries, top_p, top_k, &par);
        assert_eq!(after_total, clean_total, "post-fault merged counters");
        for (qi, r) in after.iter().enumerate() {
            assert_result_eq(
                r.as_ref().unwrap(),
                clean[qi].as_ref().unwrap(),
                &format!("post-fault query={qi}"),
            );
        }
    }

    /// A panicking shard inside the clustering engine becomes a typed
    /// `WorkerPanic` naming the shard — the scope never aborts the
    /// process — and a clean rerun reproduces the serial bits.
    #[test]
    fn clustering_shard_panic_surfaces_typed_error() {
        let _g = serialize();
        let _c = Cleanup;
        let ds = dataset(300, 0x92);
        let cfg = ClusterConfig {
            k: 6,
            seed: 5,
            ..Default::default()
        };
        let par = ParConfig {
            threads: 2,
            shard: 50,
        };
        set("algo.assign_shard", "panic@100").unwrap();
        let err = try_run_clustering_with(AlgoKind::Mivi, &ds, &cfg, &par).unwrap_err();
        match &err {
            SkmError::WorkerPanic { site, detail } => {
                assert_eq!(site, "algo.assign_shard");
                assert!(detail.contains("object 100"), "{detail}");
                assert!(detail.contains("shards panicked"), "{detail}");
            }
            other => panic!("expected WorkerPanic, got {other:?}"),
        }
        assert_eq!(err.exit_code(), 1);

        clear_all();
        let serial = try_run_clustering_with(AlgoKind::Mivi, &ds, &cfg, &ParConfig::serial())
            .unwrap();
        let rerun = try_run_clustering_with(AlgoKind::Mivi, &ds, &cfg, &par).unwrap();
        assert_eq!(rerun.assign, serial.assign, "post-fault rerun diverged");
        assert_eq!(rerun.objective.to_bits(), serial.objective.to_bits());
    }

    /// Loader failpoints surface as typed errors mid-parse: after the
    /// headers, and at an arbitrary triple index.
    #[test]
    fn loader_failpoints_yield_typed_errors() {
        let _g = serialize();
        let _c = Cleanup;
        set("loader.after_header", "error").unwrap();
        let err = skm::corpus::read_uci_bow(SAMPLE.as_bytes(), None).unwrap_err();
        assert!(
            matches!(err, SkmError::FaultInjected { .. }),
            "after_header: {err}"
        );
        assert!(err.to_string().contains("loader.after_header"), "{err}");

        clear_all();
        set("loader.triple", "error@3").unwrap();
        let err = skm::corpus::read_uci_bow(SAMPLE.as_bytes(), None).unwrap_err();
        assert!(matches!(err, SkmError::FaultInjected { .. }), "triple: {err}");
        assert!(err.to_string().contains("loader.triple"), "{err}");

        // Cleared, the same bytes parse fine.
        clear_all();
        assert!(skm::corpus::read_uci_bow(SAMPLE.as_bytes(), None).is_ok());
    }

    /// A panicking parameter estimation degrades `estimate_for` to the
    /// exact (unpruned) parameters instead of crashing the build.
    #[test]
    fn estimation_panic_degrades_to_exact_params() {
        let _g = serialize();
        let _c = Cleanup;
        let snap = snapshot(280, 0x93, 9);
        let cfg = ClusterConfig {
            k: 9,
            ..Default::default()
        };
        set("router.estimate", "panic").unwrap();
        let params = RouterParams::estimate_for(&snap, &cfg);
        assert_eq!(params, RouterParams::exact(), "degraded parameters");
        // The degraded router still routes — and exactly.
        let router = Router::new(&snap, params).unwrap();
        let q = Query::from_row(&snap.ds, 17);
        let (got, _) = router.route(&q, 3).unwrap();
        assert_eq!(got.len(), 3);
    }

    /// An injected routing error falls back to the branch-free exact
    /// scan: `route` still returns Ok, the answer is bit-identical to
    /// an exact-parameter router, and the fallback counter advances.
    #[test]
    fn routing_error_falls_back_to_exact_scan() {
        let _g = serialize();
        let _c = Cleanup;
        let snap = snapshot(320, 0x94, 10);
        let cfg = ClusterConfig {
            k: 10,
            ..Default::default()
        };
        let pruned = Router::new(&snap, RouterParams::estimate_for(&snap, &cfg)).unwrap();
        let oracle = Router::new(&snap, RouterParams::exact()).unwrap();
        let queries: Vec<Query> = (0..8).map(|i| Query::from_row(&snap.ds, i * 31)).collect();

        // Clean oracle answers first (the oracle must not route under
        // the failpoint, which would also trip it).
        let want: Vec<_> = queries
            .iter()
            .map(|q| oracle.route(q, 3).unwrap().0)
            .collect();

        set("router.route", "error").unwrap();
        for (qi, q) in queries.iter().enumerate() {
            let (got, _) = pruned.route(q, 3).unwrap();
            assert_eq!(got.len(), want[qi].len(), "query={qi}");
            for (a, b) in got.iter().zip(&want[qi]) {
                assert_eq!(a.0, b.0, "query={qi}: id under fallback");
                assert_eq!(
                    a.1.to_bits(),
                    b.1.to_bits(),
                    "query={qi}: score bits under fallback"
                );
            }
        }
        assert_eq!(
            pruned.fallback_count(),
            queries.len() as u64,
            "every faulted route must be counted"
        );

        // Cleared, the pruned path serves again and the counter stops.
        clear_all();
        let _ = pruned.route(&queries[0], 3).unwrap();
        assert_eq!(pruned.fallback_count(), queries.len() as u64);
    }

    /// `delay` actions perturb scheduling, never results: a delayed
    /// query batch is bit-identical to the clean serial baseline.
    #[test]
    fn delay_action_only_slows() {
        let _g = serialize();
        let _c = Cleanup;
        let snap = snapshot(260, 0x95, 7);
        let router = Router::new(&snap, RouterParams::exact()).unwrap();
        let queries: Vec<Query> = (0..9).map(|i| Query::from_row(&snap.ds, i * 11)).collect();
        let (clean, clean_total) = serve_batch(&router, &queries, 2, 3, &ParConfig::serial());

        set("serve.query", "delay:2@4").unwrap();
        let par = ParConfig { threads: 4, shard: 2 };
        let (got, got_total) = serve_batch(&router, &queries, 2, 3, &par);
        assert_eq!(got_total, clean_total);
        for (qi, r) in got.iter().enumerate() {
            assert_result_eq(
                r.as_ref().unwrap(),
                clean[qi].as_ref().unwrap(),
                &format!("delayed query={qi}"),
            );
        }
    }

    /// Index-maintenance failpoints are reachable and contained by the
    /// typed clustering boundary (`contain("algo.run")`): the error is
    /// a `WorkerPanic` whose detail names the maintenance site.
    #[test]
    fn maintenance_panic_is_contained_by_run_boundary() {
        let _g = serialize();
        let _c = Cleanup;
        let ds = dataset(240, 0x96);
        let cfg = ClusterConfig {
            k: 5,
            seed: 2,
            ..Default::default()
        };
        set("maintain.inv", "panic").unwrap();
        let err =
            try_run_clustering_with(AlgoKind::Icp, &ds, &cfg, &ParConfig::serial()).unwrap_err();
        match &err {
            SkmError::WorkerPanic { detail, .. } => {
                assert!(detail.contains("maintain.inv"), "{detail}");
            }
            other => panic!("expected WorkerPanic, got {other:?}"),
        }
        clear_all();
        assert!(try_run_clustering_with(AlgoKind::Icp, &ds, &cfg, &ParConfig::serial()).is_ok());
    }
}

/// With the feature disabled the macros expand to nothing: this smoke
/// test (the only one compiled) proves the harness adds no behavior,
/// and the full determinism suites (serve, parallel, golden, simd,
/// minibatch) prove bit-identity of the success path.
#[cfg(not(feature = "failpoints"))]
#[test]
fn failpoints_disabled_is_a_no_op() {
    let snap = snapshot(200, 0x97, 6);
    let router = Router::new(&snap, RouterParams::exact()).unwrap();
    let queries: Vec<Query> = (0..5).map(|i| Query::from_row(&snap.ds, i * 13)).collect();
    let (results, _) = serve_batch(&router, &queries, 2, 3, &ParConfig::serial());
    assert!(results.iter().all(|r| r.is_ok()));
    let _ = try_run_clustering_with(
        AlgoKind::Mivi,
        &snap.ds,
        &ClusterConfig {
            k: 6,
            ..Default::default()
        },
        &ParConfig::serial(),
    )
    .unwrap();
    let _ = SkmError::invalid_query("smoke".to_string());
    let _ = assert_result_eq; // the shared helpers stay exercised
}
