//! Incremental-vs-scratch index equality suite (§Perf tentpole):
//! over realistic Lloyd trajectories (3 seeds × ≥5 iterations), the
//! incrementally spliced index must be **bitwise identical** to a
//! from-scratch build for every structured index kind — offsets, ids,
//! vals (compared via `f64::to_bits`), mfm, moving_ids, and the dense
//! partial-index rows — including across the EstParams
//! re-parameterization boundary where the maintainers must fall back
//! to a full rebuild and then resume splicing.

use skm::algo::{make_assigner, seed_means, AlgoKind, Assigner, ClusterConfig, IterState};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::index::{
    membership_changes, update_means_with_rho, CsIndex, CsMaintainer, EsIndex, EsMaintainer,
    InvIndex, InvMaintainer, MeanSet, RebuildKind, TaIndex, TaMaintainer,
};
use skm::sparse::{build_dataset, Dataset};

fn dataset(seed: u64) -> Dataset {
    let c = generate(&CorpusSpec {
        n_docs: 400,
        ..tiny(seed)
    });
    build_dataset("inc", c.n_terms, &c.docs)
}

/// Drive a plain MIVI Lloyd loop, collecting the mean set after every
/// update step — the realistic moved-flag trajectory (moving fraction
/// decays, centroids relocate between the moving and invariant blocks).
fn trajectory(ds: &Dataset, cfg: &ClusterConfig, iters: usize) -> Vec<MeanSet> {
    let n = ds.n();
    let mut st = IterState {
        k: cfg.k,
        assign: vec![0; n],
        rho: vec![-1.0; n],
        xstate: vec![false; n],
        means: seed_means(ds, cfg.k, cfg.seed),
        iter: 1,
    };
    let mut assigner = make_assigner(AlgoKind::Mivi, ds, cfg);
    assigner.rebuild(ds, &st, cfg);
    let mut seq = vec![st.means.clone()];
    for r in 1..=iters {
        st.iter = r;
        let prev = st.assign.clone();
        let _ = assigner.assign(ds, &mut st);
        // No convergence break: a fixed-point step yields an all-invariant
        // mean set, which is itself a splice edge case worth covering.
        let changed = membership_changes(&prev, &st.assign, cfg.k);
        let upd = update_means_with_rho(
            ds,
            &st.assign,
            cfg.k,
            Some(&st.means),
            Some(&changed),
            Some(&st.rho),
        );
        st.means = upd.means;
        st.rho = upd.rho;
        st.iter = r + 1;
        assigner.rebuild(ds, &st, cfg);
        seq.push(st.means.clone());
    }
    assert!(seq.len() >= 6, "trajectory too short: {}", seq.len());
    seq
}

fn assert_bits_eq(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: value count");
    for (q, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: value {q}");
    }
}

fn assert_inv_eq(a: &InvIndex, b: &InvIndex, tag: &str) {
    let (ao, ai, av, am) = a.raw_parts();
    let (bo, bi, bv, bm) = b.raw_parts();
    assert_eq!(ao, bo, "{tag}: offsets");
    assert_eq!(ai, bi, "{tag}: ids");
    assert_eq!(am, bm, "{tag}: mfm");
    assert_bits_eq(av, bv, &format!("{tag}: vals"));
    assert_eq!(a.moving_ids, b.moving_ids, "{tag}: moving_ids");
    // The derived dense Region-1 tail must come out identical too (the
    // maintainers re-derive it after every splice).
    let (alo, aw) = a.dense_parts();
    let (blo, bw) = b.dense_parts();
    assert_eq!(alo, blo, "{tag}: dense_lo");
    assert_bits_eq(aw, bw, &format!("{tag}: dense_w"));
}

fn assert_region2_eq(a: &skm::index::Region2, b: &skm::index::Region2, tag: &str) {
    let (ao, ai, av, am) = a.raw_parts();
    let (bo, bi, bv, bm) = b.raw_parts();
    assert_eq!(ao, bo, "{tag}: offsets");
    assert_eq!(ai, bi, "{tag}: ids");
    assert_eq!(am, bm, "{tag}: mfm");
    assert_bits_eq(av, bv, &format!("{tag}: vals"));
}

fn assert_es_eq(a: &EsIndex, b: &EsIndex, tag: &str) {
    assert_inv_eq(&a.r1, &b.r1, &format!("{tag} r1"));
    assert_region2_eq(&a.r2, &b.r2, &format!("{tag} r2"));
    assert_bits_eq(a.partial.values(), b.partial.values(), &format!("{tag} partial"));
    assert_eq!(a.moving_ids, b.moving_ids, "{tag}: moving_ids");
}

fn assert_ta_eq(a: &TaIndex, b: &TaIndex, tag: &str) {
    assert_inv_eq(&a.r1, &b.r1, &format!("{tag} r1"));
    assert_region2_eq(&a.r2_all, &b.r2_all, &format!("{tag} r2_all"));
    assert_region2_eq(&a.r2_moving, &b.r2_moving, &format!("{tag} r2_moving"));
    assert_bits_eq(a.partial.values(), b.partial.values(), &format!("{tag} partial"));
    assert_eq!(a.moving_ids, b.moving_ids, "{tag}: moving_ids");
}

fn assert_cs_eq(a: &CsIndex, b: &CsIndex, tag: &str) {
    assert_inv_eq(&a.r1, &b.r1, &format!("{tag} r1"));
    assert_region2_eq(&a.r2_sq, &b.r2_sq, &format!("{tag} r2_sq"));
    assert_bits_eq(a.partial.values(), b.partial.values(), &format!("{tag} partial"));
    assert_eq!(a.moving_ids, b.moving_ids, "{tag}: moving_ids");
}

/// The core matrix: 3 seeds × all structured kinds × every iteration of
/// a ≥5-step realistic trajectory, incremental forced on.
#[test]
fn incremental_equals_scratch_all_kinds_seeds_iterations() {
    for seed in [11u64, 22, 33] {
        let ds = dataset(seed);
        let cfg = ClusterConfig {
            k: 12,
            seed,
            ..Default::default()
        };
        let seq = trajectory(&ds, &cfg, 12);
        let d = ds.d();
        let (t_th, v_th) = (d * 7 / 10, 0.05);

        let mut inv = InvMaintainer::new();
        let mut es = EsMaintainer::new();
        let mut ta = TaMaintainer::new();
        let mut cs = CsMaintainer::new();
        inv.max_dirty_frac = 1.0;
        es.max_dirty_frac = 1.0;
        ta.max_dirty_frac = 1.0;
        cs.max_dirty_frac = 1.0;

        for (r, means) in seq.iter().enumerate() {
            let tag = format!("seed {seed} iter {r}");
            inv.update(means, d, 1.0);
            assert_inv_eq(inv.index().unwrap(), &InvIndex::build(means, d), &tag);

            es.update(means, t_th, v_th);
            assert_es_eq(es.index().unwrap(), &EsIndex::build(means, t_th, v_th), &tag);

            ta.update(means, t_th);
            assert_ta_eq(ta.index().unwrap(), &TaIndex::build(means, t_th), &tag);

            cs.update(means, t_th);
            assert_cs_eq(cs.index().unwrap(), &CsIndex::build(means, t_th), &tag);
        }
        // The splice path (not just the fallback) must actually have run.
        for (name, incs) in [
            ("inv", inv.incremental_rebuilds),
            ("es", es.incremental_rebuilds),
            ("ta", ta.incremental_rebuilds),
            ("cs", cs.incremental_rebuilds),
        ] {
            assert!(incs >= 4, "seed {seed}: {name} spliced only {incs} times");
        }
    }
}

/// The EstParams boundary: changing `(t_th, v_th)` mid-run must fall
/// back to a full rebuild (sizes change!) and still match scratch,
/// then splicing resumes under the new parameters.
#[test]
fn estparams_reparameterization_boundary() {
    let ds = dataset(44);
    let cfg = ClusterConfig {
        k: 10,
        seed: 44,
        ..Default::default()
    };
    let seq = trajectory(&ds, &cfg, 10);
    let d = ds.d();
    // Parameter schedule mimicking the two EstParams runs: degenerate →
    // coarse estimate → final estimate, then steady state.
    let schedule: Vec<(usize, f64)> = (0..seq.len())
        .map(|r| match r {
            0 => (d, 1.0),
            1 => (d * 8 / 10, 0.08),
            _ => (d * 7 / 10, 0.04),
        })
        .collect();

    let mut es = EsMaintainer::new();
    es.max_dirty_frac = 1.0;
    for (r, means) in seq.iter().enumerate() {
        let (t_th, v_th) = schedule[r];
        es.update(means, t_th, v_th);
        let expect_full = r == 0 || schedule[r] != schedule[r - 1];
        assert_eq!(
            es.last_rebuild(),
            if expect_full {
                RebuildKind::Full
            } else {
                RebuildKind::Incremental
            },
            "iter {r}"
        );
        assert_es_eq(
            es.index().unwrap(),
            &EsIndex::build(means, t_th, v_th),
            &format!("boundary iter {r}"),
        );
    }
    assert_eq!(es.full_rebuilds, 3); // r = 0, 1, 2
    assert!(es.incremental_rebuilds as usize >= seq.len() - 3);
}

/// The production default (dirty-fraction heuristic) must agree with
/// scratch too, whichever path each iteration takes.
#[test]
fn auto_threshold_equals_scratch() {
    let ds = dataset(55);
    let cfg = ClusterConfig {
        k: 14,
        seed: 55,
        ..Default::default()
    };
    let seq = trajectory(&ds, &cfg, 10);
    let d = ds.d();
    let mut es = EsMaintainer::new(); // default max_dirty_frac
    for (r, means) in seq.iter().enumerate() {
        es.update(means, d * 7 / 10, 0.05);
        assert_es_eq(
            es.index().unwrap(),
            &EsIndex::build(means, d * 7 / 10, 0.05),
            &format!("auto iter {r}"),
        );
    }
    assert_eq!(
        es.full_rebuilds + es.incremental_rebuilds,
        seq.len() as u64
    );
}
