//! Concurrency + soundness suite for the online serving layer
//! (`serve::{ClusteredCorpus, Router, serve_batch}`):
//!
//! * **Routing soundness** — the pruned top-p centroid list (ids *and*
//!   score bits) equals a brute-force dense scan over all means, fuzzed
//!   across corpus seeds, K, p, and router parameters (estimated,
//!   degenerate-exact, and aggressive hand-picked), including zero-
//!   vector, out-of-vocabulary, single-term, and random sparse queries.
//!   The oracle scores through dense mean rows (`Σ_s u_s · μ_j[s]` in
//!   ascending term order) while the router scores through sparse
//!   merges — bit-equal by the `+0.0`-padding argument the dense
//!   Region-1 tail already rests on, so this also cross-checks that
//!   argument end to end.
//! * **Batch determinism** — `serve_batch` under `threads ∈ {2, 4, 7}`
//!   reproduces the serial loop bit for bit: per-query centroid/hit ids
//!   and score bits, per-query counters, and the merged totals.
//! * **Retrieval exactness** — the top-k documents equal a naive
//!   full-corpus scan restricted to the routed clusters' members, and a
//!   corpus document used as its own query can never be out-scored when
//!   its cluster is scanned.
//! * **Input hardening (§Robustness)** — hostile query constructions
//!   (NaN/∞/negative weights, wrong vocabulary size, strict-mode OOV)
//!   surface typed `SkmError`s and are contained per slot in
//!   `serve_batch`, never a panic or a poisoned pool.

use skm::algo::{run_clustering_with, AlgoKind, ClusterConfig, ParConfig};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::serve::{push_top, serve_batch, ClusteredCorpus, Query, Router, RouterParams};
use skm::sparse::build_dataset;
use skm::util::rng::Pcg32;

fn dataset(n_docs: usize, seed: u64) -> skm::sparse::Dataset {
    let c = generate(&CorpusSpec {
        n_docs,
        ..tiny(seed)
    });
    build_dataset("serve", c.n_terms, &c.docs)
}

/// Cluster with MIVI and freeze the result.
fn snapshot(n_docs: usize, corpus_seed: u64, k: usize, cfg_seed: u64) -> ClusteredCorpus {
    let ds = dataset(n_docs, corpus_seed);
    let cfg = ClusterConfig {
        k,
        seed: cfg_seed,
        ..Default::default()
    };
    let out = run_clustering_with(AlgoKind::Mivi, &ds, &cfg, &ParConfig::serial());
    ClusteredCorpus::from_output(ds, &out, k)
}

/// Brute-force top-p oracle: dense scan over ALL means in ascending
/// centroid id, scores accumulated over the query's terms against the
/// dense mean row (the padded zeros contribute `u·0.0 = +0.0`, a
/// bitwise no-op on the nonnegative accumulator — so these bits equal
/// the router's sparse merges), selected under the shared
/// `(score desc, id asc)` total order.
fn brute_force_route(snap: &ClusteredCorpus, q: &Query, p: usize) -> Vec<(u32, f64)> {
    let p = p.clamp(1, snap.k);
    let mut top: Vec<(f64, u32)> = Vec::new();
    for j in 0..snap.k {
        let dense = snap.means.m.row_dense(j);
        let mut sc = 0.0f64;
        for (&t, &u) in q.ids().iter().zip(q.vals()) {
            sc += u * dense[t as usize];
        }
        push_top(&mut top, p, sc, j as u32);
    }
    top.into_iter().map(|(s, j)| (j, s)).collect()
}

/// Naive retrieval oracle: score EVERY document of the routed clusters
/// through its dense row, select top-k under the shared total order.
fn brute_force_retrieve(
    snap: &ClusteredCorpus,
    q: &Query,
    routed: &[(u32, f64)],
    top_k: usize,
) -> Vec<(u32, f64)> {
    let mut top: Vec<(f64, u32)> = Vec::new();
    for &(c, _) in routed {
        for &i in snap.members(c as usize) {
            let dense = snap.ds.x.row_dense(i as usize);
            let mut sc = 0.0f64;
            for (&t, &u) in q.ids().iter().zip(q.vals()) {
                sc += u * dense[t as usize];
            }
            push_top(&mut top, top_k, sc, i);
        }
    }
    top.into_iter().map(|(s, i)| (i, s)).collect()
}

fn assert_routes_eq(got: &[(u32, f64)], want: &[(u32, f64)], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: result length");
    for (q, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.0, b.0, "{tag}: id at rank {q} ({got:?} vs {want:?})");
        assert_eq!(
            a.1.to_bits(),
            b.1.to_bits(),
            "{tag}: score bits at rank {q} ({} vs {})",
            a.1,
            b.1
        );
    }
}

/// The query mix every config is fuzzed with: corpus documents, random
/// sparse queries, and the adversarial edge cases the ISSUE names.
fn query_mix(snap: &ClusteredCorpus, rng: &mut Pcg32, n_docs: usize, n_rand: usize) -> Vec<Query> {
    let d = snap.ds.d();
    let n = snap.ds.n();
    let mut queries = Vec::new();
    for _ in 0..n_docs {
        queries.push(Query::from_row(&snap.ds, rng.gen_range(n as u32) as usize));
    }
    for _ in 0..n_rand {
        let nnz = 1 + rng.gen_range(12) as usize;
        let pairs: Vec<(u32, f64)> = rng
            .sample_distinct(d, nnz.min(d))
            .into_iter()
            .map(|t| (t as u32, 0.05 + rng.next_f64()))
            .collect();
        queries.push(Query::from_pairs(d, &pairs).unwrap());
    }
    // Zero vector; OOV-only (drops to zero); mixed OOV + in-vocab;
    // single high-df term; single low-df term.
    queries.push(Query::from_pairs(d, &[]).unwrap());
    queries.push(Query::from_pairs(d, &[(d as u32, 1.0), (d as u32 + 7, 2.0)]).unwrap());
    queries.push(
        Query::from_pairs(d, &[(d as u32 + 1, 3.0), (d as u32 - 1, 1.0), (0, 0.5)]).unwrap(),
    );
    queries.push(Query::from_pairs(d, &[(d as u32 - 1, 1.0)]).unwrap());
    queries.push(Query::from_pairs(d, &[(0, 1.0)]).unwrap());
    queries
}

/// The headline soundness property: for every fuzz case the pruned
/// router's top-p list is bit-identical to the brute-force dense scan.
#[test]
fn routing_matches_brute_force_across_seeds_k_p() {
    for (corpus_seed, n_docs, k) in [(0xA1u64, 300, 6), (0xB2, 360, 17)] {
        let snap = snapshot(n_docs, corpus_seed, k, 5);
        let cfg = ClusterConfig {
            k,
            ..Default::default()
        };
        let d = snap.ds.d();
        let params = [
            RouterParams::estimate_for(&snap, &cfg),
            RouterParams::exact(),
            // Aggressive hand-picked split: large Region 2/3, low v_th.
            RouterParams {
                t_th: d / 2,
                v_th: 0.05,
            },
        ];
        for (pi, &prm) in params.iter().enumerate() {
            let router = Router::new(&snap, prm).unwrap();
            let mut rng = Pcg32::new(corpus_seed ^ 0xfeed ^ pi as u64);
            let queries = query_mix(&snap, &mut rng, 8, 6);
            for p in [1usize, 2, 5, k] {
                for (qi, q) in queries.iter().enumerate() {
                    let (got, counters) = router.route(q, p).unwrap();
                    let want = brute_force_route(&snap, q, p);
                    let tag = format!(
                        "seed={corpus_seed:x} k={k} params#{pi} (t_th={}, v_th={}) p={p} query={qi}",
                        router.t_th(),
                        router.v_th()
                    );
                    assert_routes_eq(&got, &want, &tag);
                    // Candidate accounting: at least the survivors that
                    // made the answer, never more than K.
                    assert!(counters.candidates >= got.len() as u64, "{tag}");
                    assert!(counters.candidates <= k as u64, "{tag}");
                }
            }
        }
    }
}

/// The estimated parameters must actually prune on a corpus-shaped
/// query load (CPR < 1), otherwise the serving index is dead weight.
#[test]
fn estimated_router_prunes_candidates() {
    let snap = snapshot(400, 0xC3, 16, 9);
    let cfg = ClusterConfig {
        k: 16,
        ..Default::default()
    };
    let router = Router::new(&snap, RouterParams::estimate_for(&snap, &cfg)).unwrap();
    let mut rng = Pcg32::new(0xd00d);
    let queries = query_mix(&snap, &mut rng, 24, 0);
    let mut candidates = 0u64;
    let mut total = 0u64;
    for q in &queries {
        let (_, c) = router.route(q, 1).unwrap();
        candidates += c.candidates;
        total += snap.k as u64;
    }
    assert!(
        candidates < total,
        "router never pruned: {candidates} candidates over {total} centroid evaluations"
    );
}

/// serve_batch under 2/4/7 threads is bit-identical to the serial loop:
/// per-query ids, score bits, and counters, plus the merged totals.
#[test]
fn serve_batch_deterministic_across_thread_counts() {
    let snap = snapshot(340, 0xD4, 11, 3);
    let cfg = ClusterConfig {
        k: 11,
        ..Default::default()
    };
    let router = Router::new(&snap, RouterParams::estimate_for(&snap, &cfg)).unwrap();
    let mut rng = Pcg32::new(0xbeef);
    let queries = query_mix(&snap, &mut rng, 24, 12);
    let (top_p, top_k) = (3usize, 5usize);
    let (serial, serial_total) =
        serve_batch(&router, &queries, top_p, top_k, &ParConfig::serial());
    for threads in [2usize, 4, 7] {
        for shard in [0usize, 5] {
            let par = ParConfig { threads, shard };
            let (got, got_total) = serve_batch(&router, &queries, top_p, top_k, &par);
            let tag = format!("threads={threads} shard={shard}");
            assert_eq!(got.len(), serial.len(), "{tag}");
            for (qi, (ra, rb)) in got.iter().zip(&serial).enumerate() {
                let a = ra.as_ref().unwrap();
                let b = rb.as_ref().unwrap();
                assert_routes_eq(&a.centroids, &b.centroids, &format!("{tag} query={qi}"));
                assert_routes_eq(&a.hits, &b.hits, &format!("{tag} query={qi} hits"));
                assert_eq!(a.counters, b.counters, "{tag} query={qi} counters");
            }
            assert_eq!(got_total, serial_total, "{tag}: merged counters");
        }
    }
}

/// Retrieval exactness: the second stage's top-k equals a naive scan of
/// every document in the routed clusters, for several (p, k) shapes.
#[test]
fn retrieval_matches_restricted_full_scan() {
    let snap = snapshot(320, 0xE5, 9, 7);
    let cfg = ClusterConfig {
        k: 9,
        ..Default::default()
    };
    for prm in [
        RouterParams::estimate_for(&snap, &cfg),
        RouterParams::exact(),
    ] {
        let router = Router::new(&snap, prm).unwrap();
        let mut rng = Pcg32::new(0xcafe);
        let queries = query_mix(&snap, &mut rng, 10, 5);
        for &(top_p, top_k) in &[(1usize, 1usize), (2, 5), (3, 17), (9, 4), (2, 0)] {
            for (qi, q) in queries.iter().enumerate() {
                let r = router.retrieve(q, top_p, top_k).unwrap();
                let want = brute_force_retrieve(&snap, q, &r.centroids, top_k);
                let tag = format!(
                    "t_th={} p={top_p} k={top_k} query={qi}",
                    router.t_th()
                );
                assert_routes_eq(&r.hits, &want, &tag);
                // Every hit must belong to a routed cluster.
                for &(i, _) in &r.hits {
                    let c = snap.assign[i as usize];
                    assert!(
                        r.centroids.iter().any(|&(rc, _)| rc == c),
                        "{tag}: hit {i} outside routed clusters"
                    );
                }
                // Best-first ordering under (score desc, id asc).
                for w in r.hits.windows(2) {
                    assert!(
                        w[0].1 > w[1].1 || (w[0].1 == w[1].1 && w[0].0 < w[1].0),
                        "{tag}: hits out of order"
                    );
                }
            }
        }
    }
}

/// A corpus document used as its own query: when every cluster is
/// routed the document itself is scanned, so the best hit can never
/// score below the document's self-similarity.
#[test]
fn self_query_is_never_outscored() {
    let snap = snapshot(280, 0xF6, 8, 2);
    let router = Router::new(&snap, RouterParams::exact()).unwrap();
    for i in [0usize, 13, 97, 200] {
        let q = Query::from_row(&snap.ds, i);
        if q.is_zero() {
            continue;
        }
        let self_score: f64 = q.vals().iter().map(|v| v * v).sum();
        let r = router.retrieve(&q, snap.k, 3).unwrap();
        assert!(
            r.hits[0].1 >= self_score - 1e-12,
            "doc {i}: best hit {} below self-similarity {self_score}",
            r.hits[0].1
        );
    }
}

/// Snapshots built from the streaming driver serve identically to ones
/// built from the same assignment directly (the snapshot only depends
/// on the assignment), and ES-ICP-clustered corpora route soundly too.
#[test]
fn snapshot_sources_are_interchangeable() {
    use skm::coordinator::minibatch::{run_minibatch, BatchSchedule, MiniBatchConfig};
    let ds = dataset(300, 0x17);
    let k = 8;
    let cfg = ClusterConfig {
        k,
        seed: 21,
        ..Default::default()
    };
    let mb = MiniBatchConfig {
        batch: 75,
        schedule: BatchSchedule::Sequential,
        decay: 1.0,
        max_rounds: 24,
        sample_seed: 4,
    };
    let out = run_minibatch(AlgoKind::EsIcp, &ds, &cfg, &mb, &ParConfig::serial());
    let snap_a = ClusteredCorpus::from_minibatch(ds.clone(), &out, k);
    let snap_b = ClusteredCorpus::from_assignment(ds, out.assign.clone(), k);
    assert_eq!(snap_a.assign, snap_b.assign);
    assert_eq!(snap_a.objective.to_bits(), snap_b.objective.to_bits());
    let ra = Router::new(&snap_a, RouterParams::exact()).unwrap();
    let rb = Router::new(&snap_b, RouterParams::exact()).unwrap();
    let q = Query::from_row(&snap_a.ds, 42);
    let (a, _) = ra.route(&q, 3).unwrap();
    let (b, _) = rb.route(&q, 3).unwrap();
    assert_routes_eq(&a, &b, "minibatch vs direct snapshot");
    let want = brute_force_route(&snap_a, &q, 3);
    assert_routes_eq(&a, &want, "minibatch snapshot vs brute force");
}

/// Hostile query constructions (ISSUE §Robustness satellite): every
/// non-finite or negative weight is a typed `InvalidQuery`, never a
/// panic; strict mode additionally rejects OOV ids and zero weights;
/// a wrong-vocabulary query fails only its own `serve_batch` slot.
#[test]
fn hostile_queries_yield_typed_errors_not_panics() {
    use skm::error::SkmError;
    let snap = snapshot(260, 0x27, 7, 11);
    let d = snap.ds.d();
    let bad_weights = [
        f64::NAN,
        f64::INFINITY,
        f64::NEG_INFINITY,
        -1.0,
        -1e-300,
        f64::MIN,
    ];
    for &w in &bad_weights {
        for t in [0u32, (d / 2) as u32, d as u32 + 99] {
            let err = Query::from_pairs(d, &[(t, w)]).unwrap_err();
            assert!(
                matches!(err, SkmError::InvalidQuery { .. }),
                "weight {w} at term {t}: {err}"
            );
            assert_eq!(err.exit_code(), 1);
        }
        // Hidden among valid pairs, and via the embed_bow-adjacent
        // strict path too.
        assert!(Query::from_pairs(d, &[(0, 1.0), (1, w), (2, 0.5)]).is_err());
        assert!(Query::from_pairs_strict(d, &[(0, 1.0), (1, w)]).is_err());
    }
    // Strict mode: OOV ids and zero weights are errors, not drops.
    assert!(Query::from_pairs_strict(d, &[(d as u32, 1.0)]).is_err());
    assert!(Query::from_pairs_strict(d, &[(0, 0.0)]).is_err());
    assert!(Query::from_pairs_strict(d, &[(0, 1.0)]).is_ok());

    // A wrong-vocabulary query is contained to its own slot across
    // serial and sharded execution; neighbours stay bit-identical.
    let router = Router::new(&snap, RouterParams::exact()).unwrap();
    let mut rng = Pcg32::new(0x5afe);
    let mut queries = query_mix(&snap, &mut rng, 4, 4);
    let bad_slot = 2;
    queries[bad_slot] = Query::from_pairs(d + 13, &[(0, 1.0)]).unwrap();
    let (serial, _) = serve_batch(&router, &queries, 2, 3, &ParConfig::serial());
    for threads in [1usize, 4] {
        let par = ParConfig { threads, shard: 3 };
        let (got, _) = serve_batch(&router, &queries, 2, 3, &par);
        for (qi, r) in got.iter().enumerate() {
            if qi == bad_slot {
                let err = r.as_ref().unwrap_err();
                assert!(
                    matches!(err, SkmError::InvalidQuery { .. }),
                    "threads={threads} slot {qi}: {err}"
                );
            } else {
                let a = r.as_ref().unwrap();
                let b = serial[qi].as_ref().unwrap();
                assert_routes_eq(
                    &a.centroids,
                    &b.centroids,
                    &format!("threads={threads} query={qi}"),
                );
            }
        }
    }
}
