//! Determinism and Lloyd-parity suite for the mini-batch / streaming
//! driver (`coordinator::minibatch`), extending the `parallel.rs`
//! patterns to batched execution:
//!
//! * **same seed + any thread count ⇒ identical results** — batch
//!   selection is seed-deterministic and the batch assignment runs on
//!   the bit-identical sharded engine, so assignments, per-round merged
//!   `OpCounters`, change counts, and objective bits must agree across
//!   `threads ∈ {2, 4, 7}` and the serial path;
//! * **`batch == n`, `decay == 0` ⇒ bit-exact full-batch Lloyd** — the
//!   degenerate configuration must reproduce
//!   `algo::run_clustering_with` round for round: same assignment
//!   trajectory, same counters, same objective bits, same convergence
//!   round, for all 12 `AlgoKind`s.

use skm::algo::{run_clustering_with, AlgoKind, ClusterConfig, ParConfig};
use skm::coordinator::minibatch::{run_minibatch, BatchSchedule, MiniBatchConfig};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::sparse::build_dataset;

fn dataset(n_docs: usize, seed: u64) -> skm::sparse::Dataset {
    let c = generate(&CorpusSpec {
        n_docs,
        ..tiny(seed)
    });
    build_dataset("mb", c.n_terms, &c.docs)
}

/// (b) of the acceptance criteria: the memoryless full-span
/// configuration IS full-batch Lloyd, bit for bit, for every algorithm
/// kind — trajectory, counters, objective bits, convergence.
#[test]
fn batch_equals_n_reproduces_full_batch_lloyd_bit_exactly() {
    let ds = dataset(340, 1200);
    let cfg = ClusterConfig {
        k: 10,
        seed: 7,
        ..Default::default()
    };
    let mb = MiniBatchConfig {
        batch: ds.n(),
        schedule: BatchSchedule::Sequential,
        decay: 0.0,
        max_rounds: cfg.max_iters,
        sample_seed: 99,
    };
    for &kind in AlgoKind::all() {
        let full = run_clustering_with(kind, &ds, &cfg, &ParConfig::serial());
        let out = run_minibatch(kind, &ds, &cfg, &mb, &ParConfig::serial());
        let tag = kind.name();
        assert_eq!(out.assign, full.assign, "{tag}: assignments diverged");
        assert_eq!(out.n_rounds(), full.iterations(), "{tag}: trajectory length");
        assert_eq!(out.converged, full.converged, "{tag}: convergence");
        for (a, b) in out.rounds.iter().zip(&full.logs) {
            assert_eq!(a.round, b.iter, "{tag}");
            assert_eq!(a.counters, b.counters, "{tag}: counters at round {}", a.round);
            assert_eq!(a.changes, b.changes, "{tag}: changes at round {}", a.round);
            assert_eq!(
                a.objective.to_bits(),
                b.objective.to_bits(),
                "{tag}: objective bits at round {}",
                a.round
            );
            assert_eq!(a.n_moving, b.n_moving, "{tag}: n_moving at round {}", a.round);
            assert_eq!(a.batch_len, ds.n(), "{tag}");
        }
        assert_eq!(
            out.objective.to_bits(),
            full.objective.to_bits(),
            "{tag}: final objective"
        );
        assert_eq!(out.t_th, full.t_th, "{tag}");
        assert_eq!(out.v_th, full.v_th, "{tag}");
    }
}

/// The parallel fallback of the same contract: batch == n under the
/// sharded engine still reproduces the serial full-batch run (the span
/// path shares run_sharded with assign_par).
#[test]
fn batch_equals_n_parallel_matches_serial_lloyd() {
    let ds = dataset(300, 1300);
    let cfg = ClusterConfig {
        k: 9,
        seed: 4,
        ..Default::default()
    };
    let mb = MiniBatchConfig {
        batch: ds.n(),
        schedule: BatchSchedule::Sequential,
        decay: 0.0,
        max_rounds: cfg.max_iters,
        sample_seed: 1,
    };
    for kind in [AlgoKind::EsIcp, AlgoKind::Ding] {
        let full = run_clustering_with(kind, &ds, &cfg, &ParConfig::serial());
        let out = run_minibatch(kind, &ds, &cfg, &mb, &ParConfig::with_threads(4));
        assert_eq!(out.assign, full.assign, "{}", kind.name());
        assert_eq!(
            out.objective.to_bits(),
            full.objective.to_bits(),
            "{}",
            kind.name()
        );
    }
}

/// (a) of the acceptance criteria: seeded determinism across thread
/// counts — assignments, merged OpCounters, change counts, and
/// objective bits agree between serial and `threads ∈ {2, 4, 7}` for
/// both schedules.
#[test]
fn minibatch_deterministic_across_thread_counts() {
    let ds = dataset(390, 1400);
    let cfg = ClusterConfig {
        k: 11,
        seed: 13,
        ..Default::default()
    };
    for schedule in [BatchSchedule::Sequential, BatchSchedule::Reservoir] {
        let mb = MiniBatchConfig {
            batch: 96,
            schedule,
            decay: 1.0,
            max_rounds: 40,
            sample_seed: 21,
        };
        for kind in [
            AlgoKind::Mivi,
            AlgoKind::EsIcp,
            AlgoKind::TaIcp,
            AlgoKind::CsIcp,
            // Ding carries per-object pruning state (bounds + round
            // stamps) across rounds — the hardest case for batch
            // determinism.
            AlgoKind::Ding,
        ] {
            let serial = run_minibatch(kind, &ds, &cfg, &mb, &ParConfig::serial());
            for threads in [2usize, 4, 7] {
                let par =
                    run_minibatch(kind, &ds, &cfg, &mb, &ParConfig::with_threads(threads));
                let tag = format!(
                    "{} schedule={} threads={threads}",
                    kind.name(),
                    schedule.name()
                );
                assert_eq!(par.assign, serial.assign, "{tag}: assignments");
                assert_eq!(par.n_rounds(), serial.n_rounds(), "{tag}: rounds");
                for (a, b) in par.rounds.iter().zip(&serial.rounds) {
                    assert_eq!(
                        a.counters, b.counters,
                        "{tag}: merged counters at round {}",
                        a.round
                    );
                    assert_eq!(a.changes, b.changes, "{tag}: round {}", a.round);
                    assert_eq!(a.batch_len, b.batch_len, "{tag}: round {}", a.round);
                    assert_eq!(
                        a.objective.to_bits(),
                        b.objective.to_bits(),
                        "{tag}: objective at round {}",
                        a.round
                    );
                }
                assert_eq!(
                    par.objective.to_bits(),
                    serial.objective.to_bits(),
                    "{tag}: final objective"
                );
            }
        }
    }
}

/// Reservoir sampling is a pure function of the sampling seed: the same
/// seed replays the identical stream; a different seed draws different
/// batches (visible in the per-round trajectories).
#[test]
fn reservoir_schedule_is_seed_deterministic() {
    let ds = dataset(320, 1500);
    let cfg = ClusterConfig {
        k: 8,
        seed: 5,
        ..Default::default()
    };
    let mb = |sample_seed: u64| MiniBatchConfig {
        batch: 80,
        schedule: BatchSchedule::Reservoir,
        decay: 1.0,
        max_rounds: 24,
        sample_seed,
    };
    let a = run_minibatch(AlgoKind::EsIcp, &ds, &cfg, &mb(42), &ParConfig::serial());
    let b = run_minibatch(AlgoKind::EsIcp, &ds, &cfg, &mb(42), &ParConfig::serial());
    assert_eq!(a.assign, b.assign);
    assert_eq!(a.n_rounds(), b.n_rounds());
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.counters, y.counters, "round {}", x.round);
        assert_eq!(x.objective.to_bits(), y.objective.to_bits());
    }
    // A different sampling seed draws different batches: some round's
    // trajectory must differ (counters are batch-content-dependent).
    let c = run_minibatch(AlgoKind::EsIcp, &ds, &cfg, &mb(43), &ParConfig::serial());
    let differs = a.n_rounds() != c.n_rounds()
        || a.rounds
            .iter()
            .zip(&c.rounds)
            .any(|(x, y)| x.counters != y.counters || x.changes != y.changes);
    assert!(differs, "sampling seed had no observable effect");
}

/// Streaming bookkeeping invariants under the sequential schedule's
/// epoch wrap: every round processes exactly `b` objects (the old
/// ragged 58-object tail whose tiny m_j skewed η is gone — batch 4 of
/// a 250/64 sweep wraps into `[(0, 6), (192, 250)]`), the cyclic sweep
/// covers the corpus once every ⌈n/b⌉ rounds, and the running
/// objective stays finite.
#[test]
fn sequential_epochs_cover_every_object() {
    let ds = dataset(250, 1600);
    let cfg = ClusterConfig {
        k: 7,
        seed: 2,
        ..Default::default()
    };
    let b = 64usize; // 250 = 3·64 + 58 → round 4 wraps
    let rpe = (ds.n() + b - 1) / b;
    let mb = MiniBatchConfig {
        batch: b,
        schedule: BatchSchedule::Sequential,
        decay: 1.0,
        max_rounds: 2 * rpe,
        sample_seed: 3,
    };
    let out = run_minibatch(AlgoKind::TaIcp, &ds, &cfg, &mb, &ParConfig::serial());
    assert!(out.n_rounds() >= rpe, "fewer rounds than one epoch");
    for l in &out.rounds {
        assert_eq!(l.batch_len, b, "round {}: wrapped batches are always full", l.round);
        assert!(l.objective.is_finite());
        assert!(l.mem_bytes > 0);
    }
    assert_eq!(out.objects_processed(), out.n_rounds() * b);
    // The cyclic sweep the wrap implements covers every object at
    // least once per ⌈n/b⌉ rounds.
    let mut seen = vec![false; ds.n()];
    for q in 0..rpe * b {
        seen[q % ds.n()] = true;
    }
    assert!(seen.iter().all(|&s| s), "first {rpe} rounds cover the corpus");
}

/// Mini-batch quality sanity: a streaming run's objective lands near
/// the full-batch Lloyd objective (it cannot be bit-equal — batches
/// approximate — but it must not collapse), and count-decay keeps the
/// trajectory broadly improving.
#[test]
fn streaming_quality_tracks_full_batch() {
    let ds = dataset(420, 1700);
    let cfg = ClusterConfig {
        k: 12,
        seed: 9,
        ..Default::default()
    };
    let full = run_clustering_with(AlgoKind::EsIcp, &ds, &cfg, &ParConfig::serial());
    let b = ds.n() / 8;
    let mb = MiniBatchConfig {
        batch: b,
        schedule: BatchSchedule::Reservoir,
        decay: 1.0,
        max_rounds: 40 * ((ds.n() + b - 1) / b),
        sample_seed: 11,
    };
    let out = run_minibatch(AlgoKind::EsIcp, &ds, &cfg, &mb, &ParConfig::serial());
    assert!(
        out.objective >= 0.8 * full.objective,
        "streaming objective {} too far below full-batch {}",
        out.objective,
        full.objective
    );
    let first = out.rounds.first().unwrap().objective;
    let last = out.rounds.last().unwrap().objective;
    assert!(last >= first, "objective regressed: {first} -> {last}");
}
