//! Counting-allocator proof of the allocation-free steady-state
//! assignment loop (§Perf): once the per-shard scratch pools are warm
//! (a few Lloyd iterations), `Assigner::assign` must perform **zero**
//! heap allocations for every algorithm. This is its own integration
//! test binary so the `#[global_allocator]` cannot interfere with the
//! rest of the suite.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use skm::algo::{make_assigner, seed_means, AlgoKind, Assigner, ClusterConfig, IterState, ParConfig};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::index::{membership_changes, update_means_minibatch_inplace, update_means_with_rho, MbUpdateScratch};
use skm::sparse::build_dataset;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

/// Warm an assigner through `warm_iters` full Lloyd iterations (which
/// covers the EstParams runs at iterations 2–3 and the preset `t_th`
/// switches), then assert that further serial assignment steps do not
/// touch the allocator at all.
#[test]
fn steady_state_assignment_is_allocation_free() {
    let c = generate(&CorpusSpec {
        n_docs: 300,
        ..tiny(7)
    });
    let ds = build_dataset("alloc", c.n_terms, &c.docs);
    let cfg = ClusterConfig {
        k: 10,
        seed: 3,
        ..Default::default()
    };
    let kinds = [
        AlgoKind::Mivi,
        AlgoKind::Icp,
        AlgoKind::EsIcp,
        AlgoKind::Es,
        AlgoKind::TaIcp,
        AlgoKind::CsIcp,
        AlgoKind::Divi,
        AlgoKind::Ding,
    ];
    let n = ds.n();
    for kind in kinds {
        let mut st = IterState {
            k: cfg.k,
            assign: vec![0; n],
            rho: vec![-1.0; n],
            xstate: vec![false; n],
            means: seed_means(&ds, cfg.k, cfg.seed),
            iter: 1,
        };
        let mut assigner = make_assigner(kind, &ds, &cfg);
        assigner.rebuild(&ds, &st, &cfg);
        for r in 1..=4 {
            st.iter = r;
            let prev = st.assign.clone();
            let _ = assigner.assign(&ds, &mut st);
            let changed = membership_changes(&prev, &st.assign, cfg.k);
            let upd = update_means_with_rho(
                &ds,
                &st.assign,
                cfg.k,
                Some(&st.means),
                Some(&changed),
                Some(&st.rho),
            );
            for i in 0..n {
                st.xstate[i] = prev[i] == st.assign[i] && upd.rho[i] >= st.rho[i];
            }
            st.means = upd.means;
            st.rho = upd.rho;
            st.iter = r + 1;
            assigner.rebuild(&ds, &st, &cfg);
        }
        // Settle once after the final rebuild, drain phases, then count.
        let _ = assigner.assign(&ds, &mut st);
        let _ = assigner.take_phases();

        let before = allocs();
        for _ in 0..3 {
            let _ = assigner.assign(&ds, &mut st);
        }
        let after = allocs();
        assert_eq!(
            after - before,
            0,
            "{}: steady-state assignment allocated {} times",
            kind.name(),
            after - before
        );
    }
}

/// The mini-batch **update** step is allocation-free at steady state
/// too (§Stream cost model): once the `MbUpdateScratch` capacities, the
/// pooled λ scratch, and the RowSlab arena have plateaued (a few warmup
/// epochs), `update_means_minibatch_inplace` must splice touched rows,
/// rewrite ρ, and decay counts without touching the allocator. The
/// round stream is the driver's sequential epoch wrap with a fixed
/// assignment and `decay = 1`, so every batch still rebuilds every
/// touched cluster (the streaming-mode path) while the row supports
/// converge to their plateau.
#[test]
fn steady_state_minibatch_update_is_allocation_free() {
    let c = generate(&CorpusSpec {
        n_docs: 240,
        ..tiny(11)
    });
    let ds = build_dataset("alloc-mb", c.n_terms, &c.docs);
    let n = ds.n();
    let k = 8usize;
    let b = n / 4;
    let decay = 1.0f64;
    let par = ParConfig::serial();

    // Fixed assignment: round-robin by object id. The streaming-mode
    // changed flags (`decay > 0`) mark every cluster with batch members,
    // so each round splices b/k-member rebuilds into the slab.
    let assign: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
    let mut sizes = vec![0u32; k];
    for &a in &assign {
        sizes[a as usize] += 1;
    }
    let changed = vec![true; k];
    let mut means = seed_means(&ds, k, 5);
    let mut rho = vec![-1.0f64; n];
    let mut counts = vec![0.0f64; k];
    let mut scratch = MbUpdateScratch::new();

    let mut cursor = 0usize;
    // Reused like the driver's `runs` buffer (its capacity plateaus at
    // 2 — a run per side of the wrap — so refills are allocation-free).
    let mut runs: Vec<(usize, usize)> = Vec::with_capacity(2);
    let mut next_runs = |cursor: &mut usize, runs: &mut Vec<(usize, usize)>| {
        runs.clear();
        let lo = *cursor;
        if lo + b <= n {
            runs.push((lo, lo + b));
            *cursor = if lo + b == n { 0 } else { lo + b };
        } else {
            let rem = lo + b - n;
            runs.push((0, rem));
            runs.push((lo, n));
            *cursor = rem;
        }
    };

    // Warm up: six epochs let every scratch vector, every staged slot,
    // and every slab row span reach its plateau capacity.
    let warm_rounds = 6 * ((n + b - 1) / b);
    for _ in 0..warm_rounds {
        next_runs(&mut cursor, &mut runs);
        let _ = update_means_minibatch_inplace(
            &ds, &assign, &runs, &mut means, &mut rho, &changed, &sizes, &mut counts,
            decay, &mut scratch, &par,
        );
    }

    let before = allocs();
    for _ in 0..4 {
        next_runs(&mut cursor, &mut runs);
        let _ = update_means_minibatch_inplace(
            &ds, &assign, &runs, &mut means, &mut rho, &changed, &sizes, &mut counts,
            decay, &mut scratch, &par,
        );
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "steady-state mini-batch update allocated {} times",
        after - before
    );
}
