//! Counting-allocator proof of the allocation-free steady-state
//! assignment loop (§Perf): once the per-shard scratch pools are warm
//! (a few Lloyd iterations), `Assigner::assign` must perform **zero**
//! heap allocations for every algorithm. This is its own integration
//! test binary so the `#[global_allocator]` cannot interfere with the
//! rest of the suite.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use skm::algo::{make_assigner, seed_means, AlgoKind, Assigner, ClusterConfig, IterState};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::index::{membership_changes, update_means_with_rho};
use skm::sparse::build_dataset;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

/// Warm an assigner through `warm_iters` full Lloyd iterations (which
/// covers the EstParams runs at iterations 2–3 and the preset `t_th`
/// switches), then assert that further serial assignment steps do not
/// touch the allocator at all.
#[test]
fn steady_state_assignment_is_allocation_free() {
    let c = generate(&CorpusSpec {
        n_docs: 300,
        ..tiny(7)
    });
    let ds = build_dataset("alloc", c.n_terms, &c.docs);
    let cfg = ClusterConfig {
        k: 10,
        seed: 3,
        ..Default::default()
    };
    let kinds = [
        AlgoKind::Mivi,
        AlgoKind::Icp,
        AlgoKind::EsIcp,
        AlgoKind::Es,
        AlgoKind::TaIcp,
        AlgoKind::CsIcp,
        AlgoKind::Divi,
        AlgoKind::Ding,
    ];
    let n = ds.n();
    for kind in kinds {
        let mut st = IterState {
            k: cfg.k,
            assign: vec![0; n],
            rho: vec![-1.0; n],
            xstate: vec![false; n],
            means: seed_means(&ds, cfg.k, cfg.seed),
            iter: 1,
        };
        let mut assigner = make_assigner(kind, &ds, &cfg);
        assigner.rebuild(&ds, &st, &cfg);
        for r in 1..=4 {
            st.iter = r;
            let prev = st.assign.clone();
            let _ = assigner.assign(&ds, &mut st);
            let changed = membership_changes(&prev, &st.assign, cfg.k);
            let upd = update_means_with_rho(
                &ds,
                &st.assign,
                cfg.k,
                Some(&st.means),
                Some(&changed),
                Some(&st.rho),
            );
            for i in 0..n {
                st.xstate[i] = prev[i] == st.assign[i] && upd.rho[i] >= st.rho[i];
            }
            st.means = upd.means;
            st.rho = upd.rho;
            st.iter = r + 1;
            assigner.rebuild(&ds, &st, &cfg);
        }
        // Settle once after the final rebuild, drain phases, then count.
        let _ = assigner.assign(&ds, &mut st);
        let _ = assigner.take_phases();

        let before = allocs();
        for _ in 0..3 {
            let _ = assigner.assign(&ds, &mut st);
        }
        let after = allocs();
        assert_eq!(
            after - before,
            0,
            "{}: steady-state assignment allocated {} times",
            kind.name(),
            after - before
        );
    }
}
