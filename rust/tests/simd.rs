//! Dispatch-correctness suite for the runtime-selected SIMD kernels
//! (§Perf tentpole, PR 6): every backend the host supports must be
//! **bit-identical** to the scalar oracle on every bit-exact kernel,
//! under fuzz (SIMD-block remainders 0–7, adversarial values, the
//! dense-tail +0.0-padding cases, signed-zero argmax ties) *and* end to
//! end (a full clustering run per backend vs the scalar-forced run).
//! Requests for an ISA the host lacks must error — never select, never
//! UB.
//!
//! Backend forcing is process-global (`kernel::force_backend` swaps the
//! dispatch table all threads share), so every test that forces a
//! backend serializes on [`GUARD`] and restores auto-detection through
//! a drop guard before releasing it. Under Miri the dispatcher pins the
//! scalar table and forcing is a no-op; the suites still pass because
//! scalar-vs-scalar comparisons are trivially bit-equal.

use std::sync::Mutex;

use skm::algo::kernel::{self, Backend};
use skm::algo::{run_clustering, AlgoKind, ClusterConfig};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::sparse::build_dataset;
use skm::util::rng::Pcg32;

/// Serializes all backend-forcing tests (poison-tolerant: a failing
/// test must not cascade into "poisoned lock" noise on the rest).
static GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// Forces `b` for the guard's lifetime, restoring auto-detection on
/// drop (including on panic, so one failure cannot leak a forced
/// backend into later tests).
struct Forced;

impl Forced {
    fn new(b: Backend) -> Self {
        kernel::force_backend(b).expect("forcing a supported backend");
        Forced
    }
}

impl Drop for Forced {
    fn drop(&mut self) {
        kernel::reset_backend();
    }
}

fn random_vals(rng: &mut Pcg32, len: usize) -> Vec<f64> {
    (0..len)
        .map(|_| match rng.gen_range(12) {
            0 => 0.0,
            1 => -0.0,
            2 => -(rng.next_f64() + 0.05),
            3 => rng.next_f64() * 1e-308, // underflow-adjacent
            4 => -rng.next_f64() * 1e-308,
            _ => rng.next_f64(),
        })
        .collect()
}

/// `len` pairwise-distinct shuffled ids from `0..k` (the dispatched
/// scatter kernels' contract).
fn distinct_ids(rng: &mut Pcg32, len: usize, k: usize) -> Vec<u32> {
    assert!(len <= k);
    let mut pool: Vec<u32> = (0..k as u32).collect();
    for i in (1..pool.len()).rev() {
        let j = rng.gen_range(i as u32 + 1) as usize;
        pool.swap(i, j);
    }
    pool.truncate(len);
    pool
}

fn assert_bits(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (q, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: slot {q}: {x} vs {y}");
    }
}

#[test]
fn backend_names_resolve_and_unknown_names_error() {
    assert_eq!(kernel::resolve_backend(Some("scalar")), Ok(Backend::Scalar));
    assert_eq!(kernel::resolve_backend(Some(" Scalar ")), Ok(Backend::Scalar));
    // auto / empty / unset → detection, which must itself be supported.
    for req in [None, Some(""), Some("auto")] {
        let b = kernel::resolve_backend(req).expect("auto must resolve");
        assert!(b.is_supported(), "detected backend {b:?} unsupported");
    }
    // avx512f is an accepted alias for avx512 (resolution-level only;
    // whether it is *supported* depends on the host).
    match (
        kernel::resolve_backend(Some("avx512")),
        kernel::resolve_backend(Some("avx512f")),
    ) {
        (Ok(a), Ok(b)) => assert_eq!(a, b),
        (Err(_), Err(_)) => {}
        (a, b) => panic!("alias mismatch: {a:?} vs {b:?}"),
    }
    // Unknown names are a hard error, not a silent scalar fallback.
    assert!(kernel::resolve_backend(Some("sse9")).is_err());
    assert!(kernel::resolve_backend(Some("fastest")).is_err());
}

#[test]
fn unsupported_isa_requests_error_not_ub() {
    // At least one of these is foreign on any given host (no machine
    // supports both the x86 and the ARM vector ISA).
    let foreign: &[Backend] = if cfg!(target_arch = "x86_64") {
        &[Backend::Neon]
    } else if cfg!(target_arch = "aarch64") {
        &[Backend::Avx2, Backend::Avx512]
    } else {
        &[Backend::Avx2, Backend::Avx512, Backend::Neon]
    };
    for &b in foreign {
        assert!(!b.is_supported(), "{b:?} cannot be supported here");
        assert!(
            kernel::resolve_backend(Some(b.name())).is_err(),
            "resolving {b:?} must error on this host"
        );
        assert!(
            kernel::force_backend(b).is_err(),
            "forcing {b:?} must error on this host"
        );
    }
    // Erroring must not have clobbered the active table.
    assert!(kernel::active_backend().is_supported());
}

#[test]
fn every_supported_backend_bit_matches_the_scalar_oracle_under_fuzz() {
    let _l = lock();
    for b in Backend::available() {
        let _f = Forced::new(b);
        assert_eq!(kernel::active_backend(), b);
        fuzz_one_backend(b);
    }
}

fn fuzz_one_backend(b: Backend) {
    let tagb = b.name();
    let mut rng = Pcg32::new(0x51d3_c0de ^ (tagb.len() as u64) << 32);
    for trial in 0..300usize {
        // SIMD-block remainders 0–7 on top of random multiples of 8.
        let len = 8 * rng.gen_range(16) as usize + trial % 8;
        let k = len + 1 + rng.gen_range(48) as usize;
        let ids = distinct_ids(&mut rng, len, k);
        let vals = random_vals(&mut rng, len);
        let u = rng.next_f64() * 3.0 - 1.0;
        let init: Vec<f64> = random_vals(&mut rng, k);

        // scatter_add / scatter_add_unit vs the dup-tolerant scalar
        // oracles (distinct ids ⇒ both contracts hold).
        let mut oracle = init.clone();
        kernel::scatter_add_scalar(&mut oracle, &ids, &vals, u);
        let mut tuned = init.clone();
        // SAFETY: ids distinct, < k == tuned.len(); parallel slices.
        unsafe { kernel::scatter_add(&mut tuned, &ids, &vals, u) };
        assert_bits(&oracle, &tuned, &format!("{tagb} scatter_add t{trial}"));

        let mut oracle_u = init.clone();
        kernel::scatter_add_unit_scalar(&mut oracle_u, &ids, &vals);
        let mut tuned_u = init.clone();
        // SAFETY: as above.
        unsafe { kernel::scatter_add_unit(&mut tuned_u, &ids, &vals) };
        assert_bits(&oracle_u, &tuned_u, &format!("{tagb} unit t{trial}"));

        // dense_axpy on a +0.0-padded row (the dense-tail adversarial
        // case: absent entries are exact +0.0) into an accumulator
        // *longer* than the row, as `gather_term` does; the suffix must
        // be untouched.
        let mut row = vec![0.0f64; k];
        for (&c, &v) in ids.iter().zip(&vals) {
            row[c as usize] = v;
        }
        let acc_len = k + rng.gen_range(8) as usize;
        let init_a: Vec<f64> = random_vals(&mut rng, acc_len);
        let mut naive_a = init_a.clone();
        for j in 0..k {
            naive_a[j] += u * row[j];
        }
        let mut tuned_a = init_a.clone();
        kernel::dense_axpy(&mut tuned_a, &row, u);
        assert_bits(&naive_a, &tuned_a, &format!("{tagb} dense_axpy t{trial}"));

        // argmax_scan vs the naive scan — include exact duplicates,
        // ±0.0 (so the lowest-index-wins tie-break and which zero's
        // bits survive are exercised, not just strict maxima) and NaN
        // (which must lose every comparison without shadowing later
        // values in its SIMD lane).
        let acc: Vec<f64> = (0..k)
            .map(|_| match rng.gen_range(7) {
                0 => 0.0,
                1 => -0.0,
                2 => 0.5, // frequent exact duplicates
                3 => f64::NAN,
                _ => rng.next_f64() * 4.0 - 2.0,
            })
            .collect();
        let thresh = rng.next_f64() * 2.0 - 1.0;
        let init_id = rng.gen_range(k as u32);
        let (mut amax, mut rmax) = (init_id, thresh);
        for (j, &r) in acc.iter().enumerate() {
            if r > rmax {
                rmax = r;
                amax = j as u32;
            }
        }
        let (ga, gr) = kernel::argmax_scan(&acc, thresh, init_id);
        assert_eq!((ga, gr.to_bits()), (amax, rmax.to_bits()), "{tagb} argmax t{trial}");

        // collect_above vs the naive filter (ascending order included).
        let naive_z: Vec<u32> = (0..k as u32)
            .filter(|&j| acc[j as usize] > thresh)
            .collect();
        let mut z = Vec::new();
        kernel::collect_above(&acc, thresh, &mut z);
        assert_eq!(z, naive_z, "{tagb} collect_above t{trial}");

        // verify_axpy_ids over the ascending survivor list (the SIMD
        // fast path) and over a shuffled duplicate-laden list (the
        // prevalidation fallback), both signs.
        let dup_z: Vec<u32> = (0..len).map(|_| rng.gen_range(k as u32)).collect();
        for zl in [&naive_z, &dup_z] {
            for sign in [1.0f64, -1.0] {
                let mut naive_v = init.clone();
                let su = sign * u;
                for &j in zl {
                    naive_v[j as usize] += su * row[j as usize];
                }
                let mut tuned_v = init.clone();
                kernel::verify_axpy_ids(&mut tuned_v, zl, &row, u, sign);
                assert_bits(&naive_v, &tuned_v, &format!("{tagb} verify t{trial}"));
            }
        }

        // sparse_dot_dense stays the sequential scalar accumulator on
        // every backend unless `relaxed-simd` opted out of bit-exactness.
        #[cfg(not(feature = "relaxed-simd"))]
        {
            let mut naive_s = 0.0f64;
            for (&t, &uv) in ids.iter().zip(&vals) {
                naive_s += uv * row[t as usize];
            }
            // SAFETY: ids < k == row.len(); parallel slices.
            let got = unsafe { kernel::sparse_dot_dense(&ids, &vals, &row) };
            assert_eq!(naive_s.to_bits(), got.to_bits(), "{tagb} dot t{trial}");
        }
    }

    // Sub-width inputs take the scalar fallback inside the SIMD fns —
    // sweep every length below two full blocks.
    let mut rng = Pcg32::new(0x0ddb_a11 ^ tagb.len() as u64);
    for n in 0..32usize {
        let acc: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
        let (mut amax, mut rmax) = (7u32, -0.25f64);
        for (j, &r) in acc.iter().enumerate() {
            if r > rmax {
                rmax = r;
                amax = j as u32;
            }
        }
        assert_eq!(
            kernel::argmax_scan(&acc, -0.25, 7),
            (amax, rmax),
            "{tagb} short argmax n={n}"
        );
    }
}

#[test]
fn forced_env_and_reset_agree() {
    let _l = lock();
    // A forced backend sticks until reset, and reset honors SKM_KERNEL.
    {
        let _f = Forced::new(Backend::Scalar);
        assert_eq!(kernel::active_backend(), Backend::Scalar);
    }
    // After the guard dropped, the env var (or auto-detection when it
    // is unset) decides again. Under Miri the table is pinned scalar.
    if cfg!(miri) {
        assert_eq!(kernel::active_backend(), Backend::Scalar);
        return;
    }
    let prev = std::env::var(kernel::KERNEL_ENV).ok();
    std::env::set_var(kernel::KERNEL_ENV, "scalar");
    kernel::reset_backend();
    assert_eq!(kernel::active_backend(), Backend::Scalar);
    std::env::remove_var(kernel::KERNEL_ENV);
    kernel::reset_backend();
    assert_eq!(kernel::active_backend(), Backend::detect());
    // Put the process env back the way the harness launched it (the CI
    // matrix leg that exports SKM_KERNEL=scalar relies on it).
    if let Some(v) = prev {
        std::env::set_var(kernel::KERNEL_ENV, v);
    }
    kernel::reset_backend();
}

/// End-to-end: a full clustering run per supported backend must be
/// bit-identical to the scalar-forced run — assignments, per-iteration
/// objective bits, and final objective bits.
#[test]
fn end_to_end_cluster_runs_bit_match_scalar_across_backends() {
    let _l = lock();
    let c = generate(&CorpusSpec {
        n_docs: 240,
        ..tiny(0x51d3)
    });
    let ds = build_dataset("simd-e2e", c.n_terms, &c.docs);
    let cfg = ClusterConfig {
        k: 8,
        seed: 42,
        ..Default::default()
    };
    for kind in [AlgoKind::EsIcp, AlgoKind::Mivi] {
        let reference = {
            let _f = Forced::new(Backend::Scalar);
            run_clustering(kind, &ds, &cfg)
        };
        for b in Backend::available() {
            let _f = Forced::new(b);
            let out = run_clustering(kind, &ds, &cfg);
            let tag = format!("{} on {}", kind.name(), b.name());
            assert_eq!(out.assign, reference.assign, "{tag}: assignments");
            assert_eq!(
                out.objective.to_bits(),
                reference.objective.to_bits(),
                "{tag}: final objective"
            );
            assert_eq!(out.iterations(), reference.iterations(), "{tag}: iters");
            for (x, y) in out.logs.iter().zip(&reference.logs) {
                assert_eq!(
                    x.objective.to_bits(),
                    y.objective.to_bits(),
                    "{tag}: objective at iteration {}",
                    x.iter
                );
            }
        }
    }
}

/// The index's dense tail rows must start 64-byte aligned — the layout
/// property the SIMD `dense_axpy` loads rely on for single-line access.
#[test]
fn dense_tail_rows_are_cache_line_aligned() {
    let mut rng = Pcg32::new(0xa119_ed);
    // Top-heavy corpus so the dense tail activates (as in tests/kernel.rs).
    let d = 10usize;
    let docs: Vec<Vec<(u32, u32)>> = (0..80)
        .map(|_| {
            let mut row: Vec<(u32, u32)> = Vec::new();
            for t in 0..d as u32 {
                if rng.gen_range(d as u32 + 2) < 2 + t {
                    row.push((t, 1 + rng.gen_range(4)));
                }
            }
            if row.is_empty() {
                row.push((0, 1));
            }
            row
        })
        .collect();
    let ds = build_dataset("align", d, &docs);
    let k = 6usize;
    let assign: Vec<u32> = (0..ds.n() as u32).map(|i| i % k as u32).collect();
    let out = skm::index::update_means(&ds, &assign, k, None, None);
    let idx = skm::index::InvIndex::build(&out.means, d);
    let (dense_lo, _) = idx.dense_parts();
    assert!(dense_lo < d, "dense tail never activated");
    for s in dense_lo..d {
        let row = idx.dense_row(s).unwrap();
        assert_eq!(row.len(), k);
        assert_eq!(
            row.as_ptr() as usize % 64,
            0,
            "dense row for term {s} not 64-byte aligned"
        );
    }
}
