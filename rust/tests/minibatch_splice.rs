//! Splice-vs-scratch bit-equality suite for the in-place mini-batch
//! update (`update_means_minibatch_inplace`) against its from-scratch
//! oracle (`update_means_minibatch`).
//!
//! The in-place path splices touched rows into the live [`RowSlab`],
//! rewrites ρ only at batch-member positions, and returns an objective
//! delta; the oracle clones ρ, copies untouched rows, and rebuilds the
//! mean matrix from scratch. This suite drives both through the same
//! round stream — 3 seeds × both schedule shapes × threads {1, 2, 4, 7}
//! — and asserts after **every round** that the spliced state (mean
//! rows, `moved`, `sizes`, ρ, decayed counts) bit-matches the freshly
//! built one, and that the running objective re-summed at each epoch
//! boundary bit-matches the oracle's full re-sum.
//!
//! The batches here come from a synthetic assignment walk (seeded
//! membership flips), not a real assigner: the contract under test is
//! purely "same inputs ⇒ bit-identical update outputs", independent of
//! how the assignment was produced. End-to-end driver parity is covered
//! by `minibatch.rs`.

use skm::algo::{seed_means, ParConfig};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::index::{
    update_means_minibatch, update_means_minibatch_inplace, MbUpdateScratch, MeanSet,
};
use skm::sparse::build_dataset;
use skm::util::rng::Pcg32;

fn dataset(n_docs: usize, seed: u64) -> skm::sparse::Dataset {
    let c = generate(&CorpusSpec {
        n_docs,
        ..tiny(seed)
    });
    build_dataset("splice", c.n_terms, &c.docs)
}

/// Bit-strict mean-matrix comparison: row ids equal, row values equal
/// as raw f64 bits (RowSlab's `PartialEq` is logical, which would admit
/// `-0.0 == 0.0`).
fn assert_means_bits_eq(a: &MeanSet, b: &MeanSet, tag: &str) {
    assert_eq!(a.k(), b.k(), "{tag}: k");
    for j in 0..a.k() {
        let (ai, av) = a.m.row(j);
        let (bi, bv) = b.m.row(j);
        assert_eq!(ai, bi, "{tag}: row {j} term ids");
        for (x, y) in av.iter().zip(bv) {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: row {j} value bits");
        }
    }
    assert_eq!(a.moved, b.moved, "{tag}: moved flags");
    assert_eq!(a.sizes, b.sizes, "{tag}: sizes");
}

fn assert_f64_bits_eq(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: index {i}");
    }
}

/// Coalesce sorted distinct object ids into maximal ascending-disjoint
/// `(lo, hi)` runs — the same shape the reservoir schedule feeds the
/// update step.
fn runs_from_sorted(ids: &[usize]) -> Vec<(usize, usize)> {
    let mut runs: Vec<(usize, usize)> = Vec::new();
    for &i in ids {
        match runs.last_mut() {
            Some((_, hi)) if *hi == i => *hi += 1,
            _ => runs.push((i, i + 1)),
        }
    }
    runs
}

#[derive(Clone, Copy)]
enum Shape {
    /// Contiguous cursor windows with the driver's epoch wrap.
    Sequential,
    /// Seeded distinct samples coalesced into maximal runs.
    Scattered,
}

impl Shape {
    fn name(self) -> &'static str {
        match self {
            Shape::Sequential => "sequential",
            Shape::Scattered => "scattered",
        }
    }
}

/// One in-place state lane (per thread count): everything the driver
/// owns that the update mutates.
struct Lane {
    means: MeanSet,
    rho: Vec<f64>,
    counts: Vec<f64>,
    scratch: MbUpdateScratch,
    obj_sum: f64,
    par: ParConfig,
}

#[test]
fn inplace_update_bit_matches_scratch_oracle_every_round() {
    let k = 9usize;
    let b = 48usize;
    for (seed, decay) in [(101u64, 1.0f64), (202, 0.7), (303, 1.0)] {
        let ds = dataset(230, 1000 + seed);
        let n = ds.n();
        let rounds = 2 * ((n + b - 1) / b) + 1;
        for shape in [Shape::Sequential, Shape::Scattered] {
            let mut rng = Pcg32::new(seed ^ 0x59_11ce);
            // Shared inputs both paths consume identically.
            let mut assign: Vec<u32> = (0..n).map(|_| rng.gen_range(k as u32)).collect();
            let mut sizes = vec![0u32; k];
            for &a in &assign {
                sizes[a as usize] += 1;
            }
            let mut changed = vec![false; k];
            let init_means = seed_means(&ds, k, seed);
            let init_rho = vec![-1.0f64; n];

            // Oracle state: rebuilt from scratch every round.
            let mut o_means = init_means.clone();
            let mut o_rho = init_rho.clone();
            let mut o_counts = vec![0.0f64; k];

            // One in-place lane per thread count; all must agree with
            // the oracle (and therefore with each other) every round.
            let mut lanes: Vec<Lane> = [1usize, 2, 4, 7]
                .iter()
                .map(|&t| Lane {
                    means: init_means.clone(),
                    rho: init_rho.clone(),
                    counts: vec![0.0f64; k],
                    scratch: MbUpdateScratch::new(),
                    obj_sum: init_rho.iter().sum(),
                    par: if t == 1 {
                        ParConfig::serial()
                    } else {
                        ParConfig::with_threads(t)
                    },
                })
                .collect();

            let mut cursor = 0usize;
            let mut processed = 0usize;
            for round in 1..=rounds {
                let runs: Vec<(usize, usize)> = match shape {
                    Shape::Sequential => {
                        // The driver's epoch wrap: always a full b.
                        let lo = cursor;
                        if lo + b <= n {
                            cursor = if lo + b == n { 0 } else { lo + b };
                            vec![(lo, lo + b)]
                        } else {
                            let rem = lo + b - n;
                            cursor = rem;
                            vec![(0, rem), (lo, n)]
                        }
                    }
                    Shape::Scattered => {
                        let mut ids = rng.sample_distinct(n, b);
                        ids.sort_unstable();
                        runs_from_sorted(&ids)
                    }
                };
                let batch_len: usize = runs.iter().map(|&(lo, hi)| hi - lo).sum();
                assert_eq!(batch_len, b);

                // Synthetic assignment step: flip ~1/4 of the batch,
                // maintaining sizes and changed flags exactly like the
                // driver's bookkeeping pass.
                changed.iter_mut().for_each(|c| *c = false);
                for &(lo, hi) in &runs {
                    for i in lo..hi {
                        let was = assign[i];
                        let now = if rng.gen_range(4) == 0 {
                            rng.gen_range(k as u32)
                        } else {
                            was
                        };
                        if was != now {
                            changed[was as usize] = true;
                            changed[now as usize] = true;
                            sizes[was as usize] -= 1;
                            sizes[now as usize] += 1;
                            assign[i] = now;
                        } else if decay > 0.0 {
                            changed[now as usize] = true;
                        }
                    }
                }

                processed += batch_len;
                let epoch_boundary = processed / n > (processed - batch_len) / n;

                // Oracle: from-scratch rebuild off last round's state.
                let out = update_means_minibatch(
                    &ds, &assign, &runs, k, &o_means, &changed, &o_rho, &sizes,
                    &mut o_counts, decay,
                );
                o_means = out.means;
                o_rho = out.rho;

                for lane in &mut lanes {
                    let delta = update_means_minibatch_inplace(
                        &ds,
                        &assign,
                        &runs,
                        &mut lane.means,
                        &mut lane.rho,
                        &changed,
                        &sizes,
                        &mut lane.counts,
                        decay,
                        &mut lane.scratch,
                        &lane.par,
                    );
                    lane.obj_sum += delta;
                    if epoch_boundary {
                        lane.obj_sum = lane.rho.iter().sum();
                    }
                    let tag = format!(
                        "seed={seed} decay={decay} shape={} threads={} round={round}",
                        shape.name(),
                        lane.par.threads
                    );
                    assert_means_bits_eq(&lane.means, &o_means, &tag);
                    assert_f64_bits_eq(&lane.rho, &o_rho, &format!("{tag}: rho"));
                    assert_f64_bits_eq(&lane.counts, &o_counts, &format!("{tag}: counts"));
                    if epoch_boundary {
                        // The driver's boundary re-sum must land on the
                        // oracle's full objective, bit for bit.
                        assert_eq!(
                            lane.obj_sum.to_bits(),
                            out.objective.to_bits(),
                            "{tag}: boundary objective"
                        );
                    }
                    assert!(lane.obj_sum.is_finite(), "{tag}: running objective");
                }
            }
        }
    }
}
