//! Persistence suite (ISSUE §Persist tentpole): crash-safe snapshots
//! and checkpoints.
//!
//! * **Round-trip bit-exactness** — a saved + loaded serving snapshot
//!   is field-for-field bit-identical to the in-RAM one, and
//!   `serve_batch` over the loaded snapshot matches the never-persisted
//!   snapshot across threads ∈ {1, 2, 4, 7} (ids AND score bits).
//! * **Corruption fuzz** — truncation at every block boundary and a
//!   byte-flip sweep over every checksummed region yield a typed
//!   [`SkmError::CorruptSnapshot`]: no panic, no partial result.
//! * **Checkpoint/resume bit-equality** — a clustering run resumed from
//!   a mid-run checkpoint finishes bit-identically to the uninterrupted
//!   run (full-batch ES-ICP/Ding+/MIVI including the EstParams state
//!   machine; mini-batch sequential and reservoir including the exact
//!   sampling-RNG position).
//! * **Compressed (v2) snapshots** — the delta+varint chunk codec
//!   round-trips bit-exactly, `serve_batch` over a compressed snapshot
//!   loaded via mmap (`load_snapshot_mmap`) bit-matches the in-RAM
//!   router across threads ∈ {1, 2, 4, 7}, corrupted chunk metadata /
//!   payloads (with *valid* block CRCs, so only the chunk-level
//!   validation can catch them) are typed errors, and a committed v1
//!   fixture stays loadable on the v2 reader.
//! * **Atomic publish under injected faults** (cargo feature
//!   `failpoints`) — killing the writer at every stage (each block, the
//!   fsync, the rename) leaves the previously published file loadable
//!   and leaves no temp litter — for the v1 *and* the compressed v2
//!   writer (shared fail-point sites).
//!
//! The failpoint registry is process-global, so the injected tests
//! serialize on one mutex and clear the registry on entry and exit
//! (same harness idiom as `tests/faults.rs`).

#![cfg_attr(not(feature = "failpoints"), allow(unused_imports, dead_code))]

use skm::algo::{
    run_clustering_resumable, run_clustering_with, try_run_clustering_resumable, AlgoKind,
    ClusterConfig, ParConfig,
};
use skm::coordinator::{
    run_minibatch, run_minibatch_resumable, BatchSchedule, MiniBatchConfig,
};
use skm::error::SkmError;
use skm::persist::checkpoint::CheckpointSpec;
use skm::persist::{load_snapshot, load_snapshot_mmap, save_snapshot, save_snapshot_with};
use skm::serve::{serve_batch, ClusteredCorpus, Query, Router, RouterParams};
use skm::sparse::build_dataset;
use std::path::{Path, PathBuf};

fn dataset(n_docs: usize, seed: u64) -> skm::sparse::Dataset {
    let c = skm::corpus::generate(&skm::corpus::CorpusSpec {
        n_docs,
        ..skm::corpus::tiny(seed)
    });
    build_dataset("persist", c.n_terms, &c.docs)
}

fn cluster_config(k: usize, max_iters: usize) -> ClusterConfig {
    ClusterConfig {
        k,
        seed: 11,
        max_iters,
        ..Default::default()
    }
}

fn snapshot(n_docs: usize, k: usize) -> ClusteredCorpus {
    let ds = dataset(n_docs, 0x5a);
    let cfg = cluster_config(k, 12);
    let out = run_clustering_with(AlgoKind::EsIcp, &ds, &cfg, &ParConfig::serial());
    ClusteredCorpus::from_output(ds, &out, k)
}

/// Fresh per-test scratch directory under the OS temp dir (no external
/// tempfile crate; tagged with the pid so parallel test binaries never
/// collide).
fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("skm_persist_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Field-for-field bit comparison of two serving snapshots.
fn assert_snap_bit_eq(a: &ClusteredCorpus, b: &ClusteredCorpus) {
    assert_eq!(a.k, b.k);
    assert_eq!(a.assign, b.assign);
    assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "objective bits");
    assert_eq!(a.rho.len(), b.rho.len());
    for (i, (x, y)) in a.rho.iter().zip(&b.rho).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "rho[{i}] bits");
    }
    assert_eq!(a.means.m, b.means.m, "mean matrix");
    assert_eq!(a.means.sizes, b.means.sizes);
    assert_eq!(a.ds.x, b.ds.x, "corpus matrix");
    assert_eq!(a.ds.df, b.ds.df);
    assert_eq!(a.ds.orig_term, b.ds.orig_term);
    assert_eq!(a.ds.name, b.ds.name);
    for j in 0..a.k {
        assert_eq!(a.members(j), b.members(j), "members of cluster {j}");
    }
}

// ---------------------------------------------------------------------
// Round-trip + warm-restart equivalence

#[test]
fn snapshot_round_trip_and_warm_serve_are_bit_identical() {
    let dir = tmp_dir("roundtrip");
    let path = dir.join("snap.skm");
    let snap = snapshot(300, 8);
    let cfg = cluster_config(8, 12);
    let params = RouterParams::estimate_for(&snap, &cfg);

    let bytes = save_snapshot(&path, &snap, &params).unwrap();
    assert_eq!(std::fs::metadata(&path).unwrap().len(), bytes);
    let (loaded, lp) = load_snapshot(&path).unwrap();
    assert_eq!(lp.t_th, params.t_th);
    assert_eq!(lp.v_th.to_bits(), params.v_th.to_bits());
    assert_snap_bit_eq(&snap, &loaded);

    // Warm restart: serving answers from the loaded snapshot bit-match
    // the never-persisted snapshot for every thread count.
    let hot = Router::new(&snap, params).unwrap();
    let cold = Router::new(&loaded, lp).unwrap();
    let queries: Vec<Query> = (0..17).map(|i| Query::from_row(&snap.ds, i * 11)).collect();
    let (top_p, top_k) = (3usize, 5usize);
    let (want, _) = serve_batch(&hot, &queries, top_p, top_k, &ParConfig::serial());
    for threads in [1usize, 2, 4, 7] {
        let par = ParConfig { threads, shard: 3 };
        let (got, _) = serve_batch(&cold, &queries, top_p, top_k, &par);
        assert_eq!(got.len(), want.len());
        for (qi, (g, w)) in got.iter().zip(&want).enumerate() {
            let (g, w) = (g.as_ref().unwrap(), w.as_ref().unwrap());
            let tag = format!("threads={threads} query={qi}");
            assert_eq!(g.centroids.len(), w.centroids.len(), "{tag}");
            for (x, y) in g.centroids.iter().zip(&w.centroids) {
                assert_eq!(x.0, y.0, "{tag}: centroid id");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "{tag}: centroid score bits");
            }
            assert_eq!(g.hits.len(), w.hits.len(), "{tag}");
            for (x, y) in g.hits.iter().zip(&w.hits) {
                assert_eq!(x.0, y.0, "{tag}: hit id");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "{tag}: hit score bits");
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_rejects_checkpoint_files_and_missing_paths() {
    let dir = tmp_dir("kinds");
    let ckpt_path = dir.join("run.ckpt");
    let ds = dataset(200, 0x5a);
    let cfg = cluster_config(6, 3);
    let spec = CheckpointSpec {
        every: 0,
        path: ckpt_path.clone(),
    };
    run_clustering_resumable(
        AlgoKind::Mivi,
        &ds,
        &cfg,
        &ParConfig::serial(),
        Some(&spec),
        None,
    )
    .unwrap();
    assert!(ckpt_path.exists(), "every=0 still writes the final checkpoint");

    // A checkpoint is not a serving snapshot: typed corruption error
    // naming the header, not a panic or a half-built corpus.
    match load_snapshot(&ckpt_path).unwrap_err() {
        SkmError::CorruptSnapshot { section, .. } => assert_eq!(section, "header"),
        other => panic!("expected CorruptSnapshot, got {other:?}"),
    }
    // A missing file is an I/O error, not "corrupt".
    assert!(matches!(
        load_snapshot(&dir.join("nope.skm")).unwrap_err(),
        SkmError::Io { .. }
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Corruption fuzz: truncation + byte-flip sweep

fn expect_corrupt(path: &Path, what: &str) {
    match load_snapshot(path) {
        Err(SkmError::CorruptSnapshot { .. }) => {}
        Err(other) => panic!("{what}: expected CorruptSnapshot, got {other:?}"),
        Ok(_) => panic!("{what}: corrupted file loaded successfully"),
    }
}

#[test]
fn truncation_at_every_boundary_is_typed_corruption() {
    use skm::persist::format::{BLOCK_SIZE, FOOTER_LEN, HEADER_LEN};
    let dir = tmp_dir("trunc");
    let path = dir.join("snap.skm");
    let snap = snapshot(260, 6);
    save_snapshot(&path, &snap, &RouterParams::exact()).unwrap();
    let full = std::fs::read(&path).unwrap();
    let len = full.len();

    let mut cuts = vec![0usize, 1, HEADER_LEN - 1, HEADER_LEN];
    let mut at = HEADER_LEN + BLOCK_SIZE;
    while at < len {
        cuts.push(at); // every data-block boundary
        at += BLOCK_SIZE;
    }
    cuts.push(len - FOOTER_LEN);
    cuts.push(len - 1);

    let t = dir.join("cut.skm");
    for cut in cuts {
        std::fs::write(&t, &full[..cut]).unwrap();
        expect_corrupt(&t, &format!("truncated to {cut} of {len} bytes"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn byte_flips_in_every_checksummed_region_are_typed_corruption() {
    use skm::persist::format::{FOOTER_LEN, HEADER_LEN};
    let dir = tmp_dir("flip");
    let path = dir.join("snap.skm");
    let snap = snapshot(260, 6);
    save_snapshot(&path, &snap, &RouterParams::exact()).unwrap();
    let full = std::fs::read(&path).unwrap();
    let len = full.len();

    // The regions a flip must never survive: the header, the footer,
    // the manifest (offset parsed from the intact footer), and block
    // 0's 8-byte header + payload. (Padding bytes between a payload and
    // its block end are write-time zeros outside every checksum — a
    // flip there is undetectable by design, so the sweep excludes them.)
    let manifest_off =
        u64::from_le_bytes(full[len - FOOTER_LEN + 8..len - FOOTER_LEN + 16].try_into().unwrap())
            as usize;
    let block0_payload_len =
        u32::from_le_bytes(full[HEADER_LEN..HEADER_LEN + 4].try_into().unwrap()) as usize;
    let mut offsets: Vec<usize> = Vec::new();
    offsets.extend(0..HEADER_LEN);
    offsets.extend(len - FOOTER_LEN..len);
    offsets.extend(manifest_off..len - FOOTER_LEN);
    // Block 0 header and a payload sample (first 48 bytes + the last).
    offsets.extend(HEADER_LEN..HEADER_LEN + 8 + block0_payload_len.min(48));
    offsets.push(HEADER_LEN + 8 + block0_payload_len - 1);

    let t = dir.join("flip.skm");
    for off in offsets {
        let mut bytes = full.clone();
        bytes[off] ^= 0x40;
        std::fs::write(&t, &bytes).unwrap();
        expect_corrupt(&t, &format!("byte {off} of {len} flipped"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Compressed (v2) snapshots: round-trip, mmap serving, chunk-level
// corruption, v1 back-compat

/// Bit-compare serve results between two routers for every thread count
/// in the acceptance matrix.
fn assert_serve_bit_eq(
    hot: &Router,
    cold: &Router,
    queries: &[Query],
    top_p: usize,
    top_k: usize,
    label: &str,
) {
    let (want, _) = serve_batch(hot, queries, top_p, top_k, &ParConfig::serial());
    for threads in [1usize, 2, 4, 7] {
        let par = ParConfig { threads, shard: 3 };
        let (got, _) = serve_batch(cold, queries, top_p, top_k, &par);
        assert_eq!(got.len(), want.len());
        for (qi, (g, w)) in got.iter().zip(&want).enumerate() {
            let (g, w) = (g.as_ref().unwrap(), w.as_ref().unwrap());
            let tag = format!("{label}: threads={threads} query={qi}");
            assert_eq!(g.centroids.len(), w.centroids.len(), "{tag}");
            for (x, y) in g.centroids.iter().zip(&w.centroids) {
                assert_eq!(x.0, y.0, "{tag}: centroid id");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "{tag}: centroid score bits");
            }
            assert_eq!(g.hits.len(), w.hits.len(), "{tag}");
            for (x, y) in g.hits.iter().zip(&w.hits) {
                assert_eq!(x.0, y.0, "{tag}: hit id");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "{tag}: hit score bits");
            }
        }
    }
}

#[test]
fn compressed_round_trip_is_bit_identical_and_smaller_payload() {
    let dir = tmp_dir("v2roundtrip");
    let v1 = dir.join("v1.skm");
    let v2 = dir.join("v2.skm");
    let snap = snapshot(300, 8);
    let params = RouterParams {
        t_th: snap.ds.d() / 3,
        v_th: 0.3,
    };
    save_snapshot(&v1, &snap, &params).unwrap();
    save_snapshot_with(&v2, &snap, &params, true).unwrap();

    // Full in-RAM load of the compressed file: field-for-field bit
    // equality, including the corpus matrix.
    let (loaded, lp) = load_snapshot(&v2).unwrap();
    assert_eq!(lp.t_th, params.t_th);
    assert_eq!(lp.v_th.to_bits(), params.v_th.to_bits());
    assert_snap_bit_eq(&snap, &loaded);
    assert!(!loaded.is_disk_backed());

    // The chunked id payloads beat the raw 4 B/id encoding. File sizes
    // are block-padded (64 KiB granularity), so compare the summed
    // manifest byte lengths instead — the honest payload measure.
    let payload_bytes = |p: &Path| -> u64 {
        use skm::persist::format::{FOOTER_LEN, MANIFEST_ENTRY_LEN};
        let b = std::fs::read(p).unwrap();
        let len = b.len();
        let moff = u64::from_le_bytes(
            b[len - FOOTER_LEN + 8..len - FOOTER_LEN + 16].try_into().unwrap(),
        ) as usize;
        let count = u32::from_le_bytes(b[moff..moff + 4].try_into().unwrap()) as usize;
        (0..count)
            .map(|i| {
                let e = moff + 4 + i * MANIFEST_ENTRY_LEN;
                u64::from_le_bytes(b[e + 20..e + 28].try_into().unwrap())
            })
            .sum()
    };
    let (p1, p2) = (payload_bytes(&v1), payload_bytes(&v2));
    assert!(
        p2 < p1,
        "compressed payload {p2} not smaller than uncompressed {p1}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mmap_served_queries_bit_match_the_in_ram_router_across_threads() {
    let dir = tmp_dir("mmapserve");
    let path = dir.join("snap.skm");
    let snap = snapshot(300, 8);
    let params = RouterParams {
        t_th: snap.ds.d() / 3,
        v_th: 0.3,
    };
    save_snapshot_with(&path, &snap, &params, true).unwrap();

    // Tiny cache (clamped floor) so eviction and re-fetch actually
    // happen during the batch — correctness must not depend on
    // residency.
    let (disk_snap, dp) = load_snapshot_mmap(&path, 0).unwrap();
    assert!(disk_snap.is_disk_backed());
    assert_eq!(dp.t_th, params.t_th);

    // Every corpus row decodes to the saved bits.
    let (mut b, mut ids, mut vals) = (Vec::new(), Vec::new(), Vec::new());
    for i in 0..snap.ds.n() {
        let (ti, tv) = snap.ds.x.row(i);
        let (li, lv) = disk_snap.row_view(i, &mut b, &mut ids, &mut vals);
        assert_eq!(li, ti, "row {i} ids");
        assert!(
            lv.iter().zip(tv).all(|(x, y)| x.to_bits() == y.to_bits()),
            "row {i} value bits"
        );
    }

    let hot = Router::new(&snap, params).unwrap();
    let cold = Router::new(&disk_snap, dp).unwrap();
    let queries: Vec<Query> = (0..17).map(|i| Query::from_row(&snap.ds, i * 11)).collect();
    assert_serve_bit_eq(&hot, &cold, &queries, 3, 5, "mmap");
    let (hits, misses) = disk_snap.disk_cache_counters();
    assert!(misses > 0, "serving never touched the disk reader");
    assert!(hits + misses > 0);

    // Re-serializing a disk-backed snapshot must refuse (its in-RAM
    // corpus is a stub), not silently persist zeros.
    let err = save_snapshot(&dir.join("resave.skm"), &disk_snap, &dp).unwrap_err();
    assert_eq!(err.exit_code(), 2, "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Flip payload bytes of section `sec_id` in a block file and re-seal
/// the containing block's CRC, so container-level checks pass and only
/// chunk-level validation can catch the defect. `tweak` gets the
/// section's first block's payload slice.
fn corrupt_section_sealed(path: &Path, sec_id: u32, tweak: impl Fn(&mut [u8])) {
    use skm::persist::format::{crc32, BLOCK_SIZE, FOOTER_LEN, HEADER_LEN, MANIFEST_ENTRY_LEN};
    let mut b = std::fs::read(path).unwrap();
    let len = b.len();
    let moff = u64::from_le_bytes(
        b[len - FOOTER_LEN + 8..len - FOOTER_LEN + 16].try_into().unwrap(),
    ) as usize;
    let count = u32::from_le_bytes(b[moff..moff + 4].try_into().unwrap()) as usize;
    let mut first_block = None;
    for i in 0..count {
        let e = moff + 4 + i * MANIFEST_ENTRY_LEN;
        if u32::from_le_bytes(b[e..e + 4].try_into().unwrap()) == sec_id {
            first_block = Some(u64::from_le_bytes(b[e + 4..e + 12].try_into().unwrap()));
        }
    }
    let fb = first_block.expect("section not in manifest") as usize;
    let boff = HEADER_LEN + fb * BLOCK_SIZE;
    let payload_len = u32::from_le_bytes(b[boff..boff + 4].try_into().unwrap()) as usize;
    let payload = &mut b[boff + 8..boff + 8 + payload_len];
    tweak(payload);
    let crc = crc32(&b[boff + 8..boff + 8 + payload_len]);
    b[boff + 4..boff + 8].copy_from_slice(&crc.to_le_bytes());
    std::fs::write(path, &b).unwrap();
}

#[test]
fn chunk_level_corruption_with_valid_block_crcs_is_typed() {
    use skm::persist::sec;
    let dir = tmp_dir("chunkfuzz");
    let orig = dir.join("snap.skm");
    let snap = snapshot(300, 8);
    save_snapshot_with(&orig, &snap, &RouterParams::exact(), true).unwrap();
    let pristine = std::fs::read(&orig).unwrap();
    let t = dir.join("bad.skm");

    // (a) Chunk metadata: zero the first record's posting count (meta
    // stream = u64 chunk count, then 28-byte records starting with a
    // u32 count).
    std::fs::write(&t, &pristine).unwrap();
    corrupt_section_sealed(&t, sec::CORPUS_CHUNK_META, |p| {
        p[8..12].copy_from_slice(&0u32.to_le_bytes());
    });
    expect_corrupt(&t, "zeroed chunk posting count");
    match load_snapshot_mmap(&t, 8) {
        Err(SkmError::CorruptSnapshot { .. }) => {}
        other => panic!("mmap load of corrupt metadata: {other:?}"),
    }

    // (b) Chunk metadata: break the id-offset contiguity of record 1
    // (byte offset 8 + 28 + 8 = the second record's id_off field).
    std::fs::write(&t, &pristine).unwrap();
    corrupt_section_sealed(&t, sec::CORPUS_CHUNK_META, |p| {
        let off = 8 + 28 + 8;
        p[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
    });
    expect_corrupt(&t, "non-contiguous chunk id offset");

    // (c) Compressed id payload: zero the first varint bytes — either a
    // zero delta, a max_id mismatch, or a length mismatch, all typed.
    std::fs::write(&t, &pristine).unwrap();
    corrupt_section_sealed(&t, sec::CORPUS_CHUNK_IDS, |p| {
        for v in p.iter_mut().take(4) {
            *v = 0;
        }
    });
    expect_corrupt(&t, "zeroed id varints");
    match load_snapshot_mmap(&t, 8) {
        Err(SkmError::CorruptSnapshot { .. }) => {}
        other => panic!("mmap load of corrupt id payload: {other:?}"),
    }

    // (d) Value payload: force the first value's exponent/sign bytes to
    // a negative NaN — must fail the finite-nonnegative contract.
    std::fs::write(&t, &pristine).unwrap();
    corrupt_section_sealed(&t, sec::CORPUS_CHUNK_VALS, |p| {
        p[6] = 0xf8;
        p[7] = 0xff;
    });
    expect_corrupt(&t, "negative-NaN value bits");
    match load_snapshot_mmap(&t, 8) {
        Err(SkmError::CorruptSnapshot { .. }) => {}
        other => panic!("mmap load of corrupt value payload: {other:?}"),
    }

    // (e) Member chunk ids: same treatment as (c) for the ids-only
    // family.
    std::fs::write(&t, &pristine).unwrap();
    corrupt_section_sealed(&t, sec::MEMBER_CHUNK_IDS, |p| {
        for v in p.iter_mut().take(3) {
            *v = 0xff;
        }
    });
    expect_corrupt(&t, "mangled member id varints");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncation_and_flips_on_compressed_files_are_typed_corruption() {
    use skm::persist::format::{BLOCK_SIZE, FOOTER_LEN, HEADER_LEN};
    let dir = tmp_dir("v2fuzz");
    let path = dir.join("snap.skm");
    let snap = snapshot(260, 6);
    save_snapshot_with(&path, &snap, &RouterParams::exact(), true).unwrap();
    let full = std::fs::read(&path).unwrap();
    let len = full.len();

    let t = dir.join("cut.skm");
    for cut in [0usize, HEADER_LEN, HEADER_LEN + BLOCK_SIZE, len - FOOTER_LEN, len - 1] {
        std::fs::write(&t, &full[..cut]).unwrap();
        expect_corrupt(&t, &format!("v2 truncated to {cut} of {len} bytes"));
    }
    // Header version field (bytes 8..12) is CRC-protected.
    let mut bytes = full.clone();
    bytes[8] ^= 0x01;
    std::fs::write(&t, &bytes).unwrap();
    expect_corrupt(&t, "v2 header version flip");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Back-compat pin: a version-1 snapshot written by the pre-compression
/// code path must keep loading on the v2 reader, bit for bit.
///
/// The fixture lives in the repo (`rust/tests/snapshots/v1_fixture.skm`)
/// and is (re)generated deterministically when absent — the generator
/// is the v1 writer itself, whose byte layout is pinned by
/// `versioned_writer_stamps_header_and_v1_bytes_are_unchanged`. Once
/// committed, this test catches any reader change that strands v1 files.
#[test]
fn committed_v1_fixture_loads_on_the_v2_reader() {
    let fix_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/snapshots");
    let fix = fix_dir.join("v1_fixture.skm");
    let snap = snapshot(240, 7);
    let params = RouterParams { t_th: 9, v_th: 0.4 };
    if !fix.exists() {
        std::fs::create_dir_all(&fix_dir).unwrap();
        save_snapshot(&fix, &snap, &params).unwrap();
        eprintln!("generated v1 fixture at {} — commit it", fix.display());
    }

    let (loaded, lp) = load_snapshot(&fix).unwrap();
    assert_eq!(lp.t_th, params.t_th);
    assert_eq!(lp.v_th.to_bits(), params.v_th.to_bits());
    assert_snap_bit_eq(&snap, &loaded);

    // The mmap entry point transparently falls back to in-RAM for v1.
    let (fallback, _) = load_snapshot_mmap(&fix, 8).unwrap();
    assert!(!fallback.is_disk_backed());
    assert_snap_bit_eq(&snap, &fallback);

    // Corruption of the committed fixture stays typed (spot-check the
    // checksummed regions — header, block 0's CRC, footer; padding
    // bytes are outside every checksum by design and the exhaustive
    // region sweep runs on generated files above).
    let full = std::fs::read(&fix).unwrap();
    let len = full.len();
    let dir = tmp_dir("v1fix");
    let t = dir.join("bad.skm");
    use skm::persist::format::{FOOTER_LEN, HEADER_LEN};
    for off in [0usize, 12, HEADER_LEN + 4, len - FOOTER_LEN + 9, len - 5] {
        let mut b = full.clone();
        b[off] ^= 0x10;
        std::fs::write(&t, &b).unwrap();
        expect_corrupt(&t, &format!("fixture byte {off} flipped"));
    }
    std::fs::write(&t, &full[..full.len() - 9]).unwrap();
    expect_corrupt(&t, "fixture truncated");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Checkpoint/resume bit-equality

/// Uninterrupted run vs checkpoint-at-round-`cut` + resume: final
/// assignment, objective bits, structural parameters, and convergence
/// flag must all match.
fn assert_fullbatch_resume_matches(kind: AlgoKind, cut: usize, total: usize, threads: usize) {
    let dir = tmp_dir(&format!("resume_{}_{cut}_{threads}", kind.name()));
    let path = dir.join("run.ckpt");
    let ds = dataset(300, 0x77);
    let par = ParConfig {
        threads,
        shard: if threads > 1 { 5 } else { 0 },
    };
    let want = run_clustering_with(kind, &ds, &cluster_config(8, total), &par);

    let spec = CheckpointSpec {
        every: cut,
        path: path.clone(),
    };
    let head = run_clustering_resumable(
        kind,
        &ds,
        &cluster_config(8, cut),
        &par,
        Some(&spec),
        None,
    )
    .unwrap();
    assert!(head.iterations() <= cut);
    let got = run_clustering_resumable(
        kind,
        &ds,
        &cluster_config(8, total),
        &par,
        None,
        Some(&path),
    )
    .unwrap();

    let tag = format!("{} cut={cut} threads={threads}", kind.name());
    assert_eq!(got.assign, want.assign, "{tag}: assignment");
    assert_eq!(
        got.objective.to_bits(),
        want.objective.to_bits(),
        "{tag}: objective bits"
    );
    assert_eq!(got.t_th, want.t_th, "{tag}: t_th");
    assert_eq!(
        got.v_th.map(f64::to_bits),
        want.v_th.map(f64::to_bits),
        "{tag}: v_th bits"
    );
    assert_eq!(got.converged, want.converged, "{tag}: converged");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fullbatch_resume_is_bit_identical_esicp() {
    // cut=1 exercises the EstParams state machine: estimation #1 is in
    // the checkpoint and must not re-fire at the resumed initial
    // rebuild; estimation #2 must still fire one round later.
    assert_fullbatch_resume_matches(AlgoKind::EsIcp, 1, 8, 1);
    // cut=3: both estimations checkpointed.
    assert_fullbatch_resume_matches(AlgoKind::EsIcp, 3, 8, 1);
    // Resume under the sharded engine stays on the serial trajectory.
    assert_fullbatch_resume_matches(AlgoKind::EsIcp, 2, 8, 4);
}

#[test]
fn fullbatch_resume_is_bit_identical_ding_and_mivi() {
    // Ding+ rebuilds its drift bounds from a fresh full-evaluation
    // pass on the resumed round; MIVI is the stateless baseline.
    assert_fullbatch_resume_matches(AlgoKind::Ding, 2, 7, 1);
    assert_fullbatch_resume_matches(AlgoKind::Mivi, 2, 7, 1);
}

#[test]
fn resume_can_extend_a_finished_run() {
    // The fingerprint deliberately excludes the iteration cap: resuming
    // a completed 4-round run with a higher cap continues it, and the
    // combined trajectory bit-matches one uninterrupted longer run.
    assert_fullbatch_resume_matches(AlgoKind::EsIcp, 4, 9, 1);
}

fn mb_config(batch: usize, schedule: BatchSchedule, decay: f64, rounds: usize) -> MiniBatchConfig {
    MiniBatchConfig {
        batch,
        schedule,
        decay,
        max_rounds: rounds,
        sample_seed: 0xfeed,
    }
}

fn assert_minibatch_resume_matches(
    kind: AlgoKind,
    schedule: BatchSchedule,
    decay: f64,
    cut: usize,
    total: usize,
) {
    let dir = tmp_dir(&format!("mbresume_{}_{}_{cut}", kind.name(), schedule.name()));
    let path = dir.join("run.ckpt");
    let ds = dataset(300, 0x33);
    let cfg = cluster_config(8, 200);
    let par = ParConfig::serial();
    let want = run_minibatch(kind, &ds, &cfg, &mb_config(64, schedule, decay, total), &par);

    let spec = CheckpointSpec {
        every: cut,
        path: path.clone(),
    };
    run_minibatch_resumable(
        kind,
        &ds,
        &cfg,
        &mb_config(64, schedule, decay, cut),
        &par,
        Some(&spec),
        None,
    )
    .unwrap();
    let got = run_minibatch_resumable(
        kind,
        &ds,
        &cfg,
        &mb_config(64, schedule, decay, total),
        &par,
        None,
        Some(&path),
    )
    .unwrap();

    let tag = format!("{} {} decay={decay} cut={cut}", kind.name(), schedule.name());
    assert_eq!(got.assign, want.assign, "{tag}: assignment");
    assert_eq!(
        got.objective.to_bits(),
        want.objective.to_bits(),
        "{tag}: objective bits"
    );
    assert_eq!(got.converged, want.converged, "{tag}: converged");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn minibatch_resume_is_bit_identical_sequential() {
    // Sequential + count decay: the checkpoint carries the batch
    // cursor, decay counts, and staleness clocks.
    assert_minibatch_resume_matches(AlgoKind::EsIcp, BatchSchedule::Sequential, 1.0, 5, 12);
}

#[test]
fn minibatch_resume_is_bit_identical_reservoir() {
    // Reservoir sampling: the checkpoint carries the exact RNG stream
    // position, so the resumed run draws the same remaining batches.
    assert_minibatch_resume_matches(AlgoKind::Mivi, BatchSchedule::Reservoir, 0.0, 4, 10);
}

// ---------------------------------------------------------------------
// Fingerprint and kind guards

#[test]
fn resume_with_mismatched_config_is_invalid_config() {
    let dir = tmp_dir("fpguard");
    let path = dir.join("run.ckpt");
    let ds = dataset(220, 0x21);
    let spec = CheckpointSpec {
        every: 2,
        path: path.clone(),
    };
    run_clustering_resumable(
        AlgoKind::EsIcp,
        &ds,
        &cluster_config(6, 2),
        &ParConfig::serial(),
        Some(&spec),
        None,
    )
    .unwrap();

    // Different seed → typed usage error (exit 2) naming the field.
    let mut other = cluster_config(6, 8);
    other.seed = 12;
    let err = try_run_clustering_resumable(
        AlgoKind::EsIcp,
        &ds,
        &other,
        &ParConfig::serial(),
        None,
        Some(&path),
    )
    .unwrap_err();
    assert_eq!(err.exit_code(), 2, "{err}");
    assert!(err.to_string().contains("seed"), "{err}");

    // Different algorithm → same guard.
    let err = try_run_clustering_resumable(
        AlgoKind::Mivi,
        &ds,
        &cluster_config(6, 8),
        &ParConfig::serial(),
        None,
        Some(&path),
    )
    .unwrap_err();
    assert_eq!(err.exit_code(), 2, "{err}");

    // Different corpus content → digest mismatch.
    let ds2 = dataset(220, 0x22);
    let err = try_run_clustering_resumable(
        AlgoKind::EsIcp,
        &ds2,
        &cluster_config(6, 8),
        &ParConfig::serial(),
        None,
        Some(&path),
    )
    .unwrap_err();
    assert_eq!(err.exit_code(), 2, "{err}");

    // A full-batch checkpoint is not a mini-batch checkpoint.
    let err = run_minibatch_resumable(
        AlgoKind::EsIcp,
        &ds,
        &cluster_config(6, 8),
        &mb_config(64, BatchSchedule::Sequential, 1.0, 8),
        &ParConfig::serial(),
        None,
        Some(&path),
    )
    .unwrap_err();
    assert!(
        matches!(err, SkmError::CorruptSnapshot { ref section, .. } if section == "header"),
        "{err:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Atomic publish under injected faults

#[cfg(feature = "failpoints")]
mod injected {
    use super::*;
    use skm::util::failpoint::{clear_all, set};
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// The registry is process-global; tests must not interleave.
    fn serialize() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        let guard = LOCK
            .get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        clear_all();
        guard
    }

    /// Clears the registry when a test exits, pass or fail.
    struct Cleanup;
    impl Drop for Cleanup {
        fn drop(&mut self) {
            clear_all();
        }
    }

    fn no_temp_litter(dir: &Path) {
        let litter: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(litter.is_empty(), "temp files left behind: {litter:?}");
    }

    /// Tentpole proof: kill the snapshot writer at every stage — each
    /// data block (first, middle, last), the fsync, the rename. The
    /// previously published snapshot must stay loadable and bit-intact,
    /// and the failed attempt must leave no temp file behind. After the
    /// fault clears, publishing succeeds.
    #[test]
    fn killed_writes_never_damage_the_published_snapshot() {
        let _g = serialize();
        let _c = Cleanup;
        let dir = tmp_dir("atomic");
        let path = dir.join("snap.skm");
        let snap = snapshot(260, 6);
        let params_v1 = RouterParams::exact();
        save_snapshot(&path, &snap, &params_v1).unwrap();
        let published = std::fs::read(&path).unwrap();

        // How many blocks does this snapshot span? (Parsed from the
        // intact header: n_blocks is the u64 at offset 24.)
        let n_blocks = u64::from_le_bytes(published[24..32].try_into().unwrap());
        assert!(n_blocks >= 3, "fixture too small to kill first/middle/last");

        let kill_specs: [(&str, String); 5] = [
            ("persist.write_block", "error@0".to_string()),
            ("persist.write_block", format!("error@{}", n_blocks / 2)),
            ("persist.write_block", format!("error@{}", n_blocks - 1)),
            ("persist.fsync", "error".to_string()),
            ("persist.rename", "error".to_string()),
        ];
        let params_v2 = RouterParams {
            t_th: 3,
            v_th: 0.5,
        };
        for (site, spec) in &kill_specs {
            set(site, spec).unwrap();
            let err = save_snapshot(&path, &snap, &params_v2).unwrap_err();
            assert!(
                matches!(err, SkmError::FaultInjected { .. }),
                "{site} {spec}: {err:?}"
            );
            clear_all();
            no_temp_litter(&dir);
            assert_eq!(
                std::fs::read(&path).unwrap(),
                published,
                "{site} {spec}: published file changed"
            );
            let (loaded, lp) = load_snapshot(&path).unwrap();
            assert_snap_bit_eq(&snap, &loaded);
            assert_eq!(lp.t_th, params_v1.t_th, "{site} {spec}");
        }

        // Faults cleared: the next publish goes through and wins.
        save_snapshot(&path, &snap, &params_v2).unwrap();
        let (_, lp) = load_snapshot(&path).unwrap();
        assert_eq!(lp.t_th, params_v2.t_th);
        assert_eq!(lp.v_th.to_bits(), params_v2.v_th.to_bits());
        no_temp_litter(&dir);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The compressed (v2) writer shares the fail-point-instrumented
    /// publish path with v1 — prove it, don't assume it: kill the v2
    /// writer at every stage over a previously published v1 snapshot.
    /// The v1 file must stay bit-intact and loadable, no temp litter,
    /// and once the fault clears the v2 publish wins and loads back
    /// bit-exactly (the cross-version upgrade-in-place story).
    #[test]
    fn killed_compressed_writes_never_damage_the_published_snapshot() {
        let _g = serialize();
        let _c = Cleanup;
        let dir = tmp_dir("atomic_v2");
        let path = dir.join("snap.skm");
        let snap = snapshot(260, 6);
        let params = RouterParams::exact();
        save_snapshot(&path, &snap, &params).unwrap();
        let published = std::fs::read(&path).unwrap();
        let n_blocks = u64::from_le_bytes(published[24..32].try_into().unwrap());
        assert!(n_blocks >= 3, "fixture too small to kill first/middle/last");

        let kill_specs: [(&str, String); 5] = [
            ("persist.write_block", "error@0".to_string()),
            ("persist.write_block", format!("error@{}", n_blocks / 2)),
            ("persist.write_block", format!("error@{}", n_blocks - 1)),
            ("persist.fsync", "error".to_string()),
            ("persist.rename", "error".to_string()),
        ];
        for (site, spec) in &kill_specs {
            set(site, spec).unwrap();
            let err = save_snapshot_with(&path, &snap, &params, true).unwrap_err();
            assert!(
                matches!(err, SkmError::FaultInjected { .. }),
                "{site} {spec}: {err:?}"
            );
            clear_all();
            no_temp_litter(&dir);
            assert_eq!(
                std::fs::read(&path).unwrap(),
                published,
                "{site} {spec}: published v1 file changed under a killed v2 write"
            );
            let (loaded, _) = load_snapshot(&path).unwrap();
            assert_snap_bit_eq(&snap, &loaded);
        }

        // Fault cleared: the compressed publish replaces the v1 file
        // atomically and round-trips bit-exactly.
        save_snapshot_with(&path, &snap, &params, true).unwrap();
        assert_ne!(std::fs::read(&path).unwrap(), published, "v2 bytes differ");
        let (loaded, _) = load_snapshot(&path).unwrap();
        assert_snap_bit_eq(&snap, &loaded);
        no_temp_litter(&dir);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Read-side faults surface as typed errors too (a failing disk on
    /// load is not a crash), and a clean retry succeeds.
    #[test]
    fn read_faults_are_typed_and_transient() {
        let _g = serialize();
        let _c = Cleanup;
        let dir = tmp_dir("readfault");
        let path = dir.join("snap.skm");
        let snap = snapshot(260, 6);
        save_snapshot(&path, &snap, &RouterParams::exact()).unwrap();

        set("persist.read_block", "error@1").unwrap();
        let err = load_snapshot(&path).unwrap_err();
        assert!(matches!(err, SkmError::FaultInjected { .. }), "{err:?}");
        clear_all();
        let (loaded, _) = load_snapshot(&path).unwrap();
        assert_snap_bit_eq(&snap, &loaded);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A checkpoint write killed mid-run surfaces as a typed error from
    /// the resumable driver, and the previous checkpoint (if any)
    /// remains usable for resume.
    #[test]
    fn killed_checkpoint_write_keeps_previous_checkpoint_usable() {
        let _g = serialize();
        let _c = Cleanup;
        let dir = tmp_dir("ckptkill");
        let path = dir.join("run.ckpt");
        let ds = dataset(260, 0x44);
        let par = ParConfig::serial();
        let spec = CheckpointSpec {
            every: 1,
            path: path.clone(),
        };

        // Publish the round-1 checkpoint cleanly.
        run_clustering_resumable(
            AlgoKind::Mivi,
            &ds,
            &cluster_config(6, 1),
            &par,
            Some(&spec),
            None,
        )
        .unwrap();
        let round1 = std::fs::read(&path).unwrap();

        // Kill the round-2 checkpoint publish (second write in this
        // process hits the same site; fail its rename).
        set("persist.rename", "error").unwrap();
        let err = try_run_clustering_resumable(
            AlgoKind::Mivi,
            &ds,
            &cluster_config(6, 2),
            &par,
            Some(&spec),
            None,
        )
        .unwrap_err();
        assert!(matches!(err, SkmError::FaultInjected { .. }), "{err:?}");
        clear_all();
        assert_eq!(std::fs::read(&path).unwrap(), round1, "checkpoint torn");

        // The surviving round-1 checkpoint resumes to the same final
        // state as the uninterrupted run.
        let want = run_clustering_with(AlgoKind::Mivi, &ds, &cluster_config(6, 6), &par);
        let got = run_clustering_resumable(
            AlgoKind::Mivi,
            &ds,
            &cluster_config(6, 6),
            &par,
            None,
            Some(&path),
        )
        .unwrap();
        assert_eq!(got.assign, want.assign);
        assert_eq!(got.objective.to_bits(), want.objective.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Without the `failpoints` feature the injected suite compiles away;
/// this smoke test keeps the binary non-empty and proves the disabled
/// harness changes nothing observable in a save/load cycle.
#[cfg(not(feature = "failpoints"))]
#[test]
fn persist_without_failpoints_smoke() {
    let dir = tmp_dir("nofp");
    let path = dir.join("snap.skm");
    let snap = snapshot(200, 6);
    save_snapshot(&path, &snap, &RouterParams::exact()).unwrap();
    let (loaded, _) = load_snapshot(&path).unwrap();
    assert_snap_bit_eq(&snap, &loaded);
    let _ = std::fs::remove_dir_all(&dir);
}
