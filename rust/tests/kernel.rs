//! Property/fuzz-style bit-identity suite for the gather micro-kernels
//! (§Perf tentpole): the dispatched kernels in `skm::algo::kernel`
//! (scalar-unrolled on this binary's default path; the SIMD backends
//! are additionally swept in `tests/simd.rs`) must be **bit-identical**
//! to the naive bounds-checked scalar scatter-add across random
//! posting lengths (covering the SIMD-block remainders 0–7), empty
//! slices, adversarial values (negative, underflowing, exact zeros),
//! and through a real `InvIndex` with an active dense Region-1 tail.
//! The dispatched scatter kernels require pairwise-distinct ids (the
//! SIMD gather/scatter contract); duplicate-id accumulation order is
//! covered on the kernels that remain dup-tolerant — the scalar
//! oracles, `scatter_add_versioned`, and `verify_axpy_ids`. Mismatched
//! posting-array lengths are a hard error on every path (no silent
//! release-mode truncation). This binary is also the Miri target for
//! the unsafe indexing (see the CI `miri` job; Miri always runs the
//! scalar table).

use skm::algo::kernel;
use skm::index::{update_means, InvIndex};
use skm::sparse::build_dataset;
use skm::util::rng::Pcg32;

fn random_vals(rng: &mut Pcg32, len: usize) -> Vec<f64> {
    (0..len)
        .map(|_| match rng.gen_range(12) {
            0 => 0.0,
            1 => -(rng.next_f64() + 0.05),
            2 => rng.next_f64() * 1e-308, // underflow-adjacent
            3 => -rng.next_f64() * 1e-308,
            _ => rng.next_f64(),
        })
        .collect()
}

/// `len` pairwise-distinct ids drawn from `0..k`, in shuffled order
/// (Fisher–Yates) — the distinct-ids contract of the dispatched
/// scatter kernels, with arbitrary (non-ascending) order still allowed.
fn distinct_ids(rng: &mut Pcg32, len: usize, k: usize) -> Vec<u32> {
    assert!(len <= k);
    let mut pool: Vec<u32> = (0..k as u32).collect();
    for i in (1..pool.len()).rev() {
        let j = rng.gen_range(i as u32 + 1) as usize;
        pool.swap(i, j);
    }
    pool.truncate(len);
    pool
}

#[test]
fn scatter_add_bit_identical_across_lengths_and_remainders() {
    let mut rng = Pcg32::new(0xbead_cafe);
    for trial in 0..500usize {
        // Length schedule sweeps the SIMD-block remainders 0–7
        // explicitly (trial % 8) on top of random multiples of 8.
        let len = 8 * rng.gen_range(16) as usize + trial % 8;
        // Distinct shuffled ids < k (the dispatched-kernel contract).
        let k = len + 1 + rng.gen_range(32) as usize;
        let ids = distinct_ids(&mut rng, len, k);
        let vals = random_vals(&mut rng, len);
        let u = rng.next_f64() * 3.0 - 1.0;
        // Accumulators start at arbitrary nonnegative values (what the
        // assigners do: 0.0 or y_base ≥ 0).
        let init: Vec<f64> = (0..k).map(|_| rng.next_f64()).collect();

        let mut naive = init.clone();
        kernel::scatter_add_scalar(&mut naive, &ids, &vals, u);
        let mut tuned = init.clone();
        // SAFETY: ids were generated < k == tuned.len(), pairwise
        // distinct; parallel slices.
        unsafe { kernel::scatter_add(&mut tuned, &ids, &vals, u) };
        for (q, (a, b)) in naive.iter().zip(&tuned).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "trial {trial} slot {q}: {a} vs {b}"
            );
        }

        let mut naive_u = init.clone();
        kernel::scatter_add_unit_scalar(&mut naive_u, &ids, &vals);
        let mut tuned_u = init;
        // SAFETY: as above.
        unsafe { kernel::scatter_add_unit(&mut tuned_u, &ids, &vals) };
        for (a, b) in naive_u.iter().zip(&tuned_u) {
            assert_eq!(a.to_bits(), b.to_bits(), "unit trial {trial}");
        }
    }
}

#[test]
fn scalar_oracles_accumulate_duplicates_in_posting_order() {
    // The scalar oracles stay duplicate-tolerant (sequential += in
    // posting order) — that is what makes them the reference for the
    // index builders' one-posting-per-centroid invariant rather than a
    // mirror of the SIMD contract.
    let mut rng = Pcg32::new(0xd0b1_5eed);
    for trial in 0..200usize {
        let k = 1 + rng.gen_range(24) as usize;
        let len = 4 * rng.gen_range(12) as usize + trial % 4;
        let bound = 1 + rng.gen_range(k as u32);
        let ids: Vec<u32> = (0..len).map(|_| rng.gen_range(bound)).collect();
        let vals = random_vals(&mut rng, len);
        let u = rng.next_f64() * 2.0 - 0.5;
        let init: Vec<f64> = (0..k).map(|_| rng.next_f64()).collect();

        let mut naive = init.clone();
        for (&c, &v) in ids.iter().zip(&vals) {
            naive[c as usize] += u * v;
        }
        let mut oracle = init.clone();
        kernel::scatter_add_scalar(&mut oracle, &ids, &vals, u);
        for (a, b) in naive.iter().zip(&oracle) {
            assert_eq!(a.to_bits(), b.to_bits(), "trial {trial}");
        }

        let mut naive_u = init.clone();
        for (&c, &v) in ids.iter().zip(&vals) {
            naive_u[c as usize] += v;
        }
        let mut oracle_u = init;
        kernel::scatter_add_unit_scalar(&mut oracle_u, &ids, &vals);
        for (a, b) in naive_u.iter().zip(&oracle_u) {
            assert_eq!(a.to_bits(), b.to_bits(), "unit trial {trial}");
        }
    }
}

#[test]
#[should_panic(expected = "parallel")]
fn scatter_add_rejects_mismatched_lengths() {
    let mut acc = vec![0.0f64; 4];
    // Ids are in range; only the lengths disagree. Must panic (hard
    // error), never silently truncate.
    // SAFETY: ids < acc.len(); the length mismatch is the point.
    unsafe { kernel::scatter_add(&mut acc, &[0, 1], &[1.0], 2.0) };
}

#[test]
#[should_panic(expected = "parallel")]
fn scatter_add_unit_rejects_mismatched_lengths() {
    let mut acc = vec![0.0f64; 4];
    // SAFETY: as above.
    unsafe { kernel::scatter_add_unit(&mut acc, &[2], &[1.0, 1.0]) };
}

#[test]
#[should_panic(expected = "parallel")]
fn scalar_oracle_rejects_mismatched_lengths() {
    let mut acc = vec![0.0f64; 4];
    kernel::scatter_add_scalar(&mut acc, &[0, 1], &[1.0], 2.0);
}

#[test]
#[should_panic(expected = "parallel")]
fn sparse_dot_dense_rejects_mismatched_lengths() {
    let row = vec![1.0f64; 4];
    // SAFETY: term ids < row.len(); the length mismatch is the point.
    unsafe { kernel::sparse_dot_dense(&[0, 1], &[1.0], &row) };
}

#[test]
#[should_panic(expected = "parallel")]
fn versioned_scatter_rejects_mismatched_lengths() {
    let mut score = vec![0.0f64; 3];
    let mut version = vec![0u32; 3];
    let mut touched = Vec::new();
    // SAFETY: ids in [lo, lo + score.len()); the length mismatch is
    // the point.
    unsafe {
        kernel::scatter_add_versioned(
            &mut score,
            &mut version,
            &mut touched,
            1,
            &[5, 6],
            &[1.0],
            2.0,
            5,
        )
    };
}

#[test]
fn empty_slices_are_noops() {
    let mut acc = vec![0.25f64, -1.5, 3.0];
    let snapshot = acc.clone();
    // SAFETY: empty posting slices trivially satisfy the id contract.
    unsafe {
        kernel::scatter_add(&mut acc, &[], &[], 7.0);
        kernel::scatter_add_unit(&mut acc, &[], &[]);
    }
    assert_eq!(acc, snapshot);
    let (amax, rmax) = kernel::argmax_ids(&acc, &[], 9.0, 2);
    assert_eq!((amax, rmax), (2, 9.0));
    let mut z = vec![1u32];
    kernel::collect_above_ids(&acc, &[], f64::NEG_INFINITY, &mut z);
    assert!(z.is_empty());
}

#[test]
fn dense_axpy_equals_sparse_scatter_with_zero_padding() {
    // The +0.0-padding argument from the kernel module docs, fuzzed:
    // for accumulators initialized at +0.0 (or any value reachable by
    // accumulation from +0.0), adding `u·0.0` for absent entries is a
    // bitwise no-op, so the dense row gather matches the sparse scatter
    // even with negative and underflowing values in play.
    let mut rng = Pcg32::new(0x00d5_ee1d);
    for trial in 0..300usize {
        let k = 1 + rng.gen_range(48) as usize;
        let mut row = vec![0.0f64; k];
        let mut ids = Vec::new();
        let mut vals = Vec::new();
        for j in 0..k {
            if rng.gen_range(4) != 0 {
                let v = match rng.gen_range(6) {
                    0 => -(rng.next_f64() + 0.01),
                    1 => rng.next_f64() * 1e-308,
                    _ => rng.next_f64(),
                };
                row[j] = v;
                ids.push(j as u32);
                vals.push(v);
            }
        }
        let u = rng.next_f64() * 2.0;
        let mut sparse = vec![0.0f64; k];
        kernel::scatter_add_scalar(&mut sparse, &ids, &vals, u);
        let mut dense = vec![0.0f64; k];
        kernel::dense_axpy(&mut dense, &row, u);
        for (j, (a, b)) in sparse.iter().zip(&dense).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "trial {trial} slot {j}");
        }
    }
}

#[test]
fn argmax_and_filter_kernels_match_naive_scans() {
    let mut rng = Pcg32::new(0x5ee_d00d);
    for _ in 0..200 {
        let k = 1 + rng.gen_range(40) as usize;
        let acc: Vec<f64> = (0..k).map(|_| rng.next_f64() * 4.0 - 2.0).collect();
        let thresh = rng.next_f64() * 2.0 - 1.0;
        let init_a = rng.gen_range(k as u32);

        let (mut amax, mut rmax) = (init_a, thresh);
        for (j, &r) in acc.iter().enumerate() {
            if r > rmax {
                rmax = r;
                amax = j as u32;
            }
        }
        assert_eq!(kernel::argmax_scan(&acc, thresh, init_a), (amax, rmax));

        let subset: Vec<u32> = (0..k as u32).filter(|_| rng.gen_range(3) > 0).collect();
        let (mut am, mut rm) = (init_a, thresh);
        let mut keep = Vec::new();
        for &j in &subset {
            if acc[j as usize] > thresh {
                keep.push(j);
            }
            if acc[j as usize] > rm {
                rm = acc[j as usize];
                am = j;
            }
        }
        assert_eq!(kernel::argmax_ids(&acc, &subset, thresh, init_a), (am, rm));
        let mut z = Vec::new();
        kernel::collect_above_ids(&acc, &subset, thresh, &mut z);
        assert_eq!(z, keep);

        let full: Vec<u32> = (0..k as u32).filter(|&j| acc[j as usize] > thresh).collect();
        kernel::collect_above(&acc, thresh, &mut z);
        assert_eq!(z, full);
    }
}

#[test]
fn verify_axpy_matches_naive_loop_both_signs() {
    let mut rng = Pcg32::new(0xfee1_600d);
    for _ in 0..100 {
        let k = 1 + rng.gen_range(32) as usize;
        let row: Vec<f64> = (0..k).map(|_| rng.next_f64() - 0.4).collect();
        let init: Vec<f64> = (0..k).map(|_| rng.next_f64()).collect();
        let z: Vec<u32> = (0..k as u32).filter(|_| rng.gen_range(2) == 0).collect();
        let u = rng.next_f64() + 0.1;
        for sign in [1.0f64, -1.0] {
            let mut naive = init.clone();
            let su = sign * u;
            for &j in &z {
                naive[j as usize] += su * row[j as usize];
            }
            let mut tuned = init.clone();
            kernel::verify_axpy_ids(&mut tuned, &z, &row, u, sign);
            for (a, b) in naive.iter().zip(&tuned) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}

#[test]
fn verify_axpy_handles_duplicate_and_unsorted_survivors() {
    // `verify_axpy_ids` is a *safe* fn over arbitrary survivor lists:
    // the SIMD backends prevalidate (strictly ascending, in-bounds)
    // and fall back to the scalar loop otherwise, so duplicates and
    // unsorted ids keep exact sequential += semantics on every
    // backend. The assigners only ever pass `collect_above*` output,
    // but the safe contract must hold regardless.
    let mut rng = Pcg32::new(0xca11_ab1e);
    for trial in 0..100usize {
        let k = 2 + rng.gen_range(24) as usize;
        let row: Vec<f64> = (0..k).map(|_| rng.next_f64() - 0.3).collect();
        let init: Vec<f64> = (0..k).map(|_| rng.next_f64()).collect();
        // Random order, duplicates likely.
        let len = 1 + rng.gen_range(3 * k as u32) as usize;
        let z: Vec<u32> = (0..len).map(|_| rng.gen_range(k as u32)).collect();
        let u = rng.next_f64() + 0.05;
        for sign in [1.0f64, -1.0] {
            let mut naive = init.clone();
            let su = sign * u;
            for &j in &z {
                naive[j as usize] += su * row[j as usize];
            }
            let mut tuned = init.clone();
            kernel::verify_axpy_ids(&mut tuned, &z, &row, u, sign);
            for (a, b) in naive.iter().zip(&tuned) {
                assert_eq!(a.to_bits(), b.to_bits(), "trial {trial} sign {sign}");
            }
        }
    }
}

/// The default build keeps `sparse_dot_dense` on the sequential scalar
/// accumulator on every backend; the opt-in `relaxed-simd` feature
/// documents away exactly this guarantee, so the test is gated off
/// under it.
#[cfg(not(feature = "relaxed-simd"))]
#[test]
fn sparse_dot_dense_is_order_exact() {
    let mut rng = Pcg32::new(0xd1d_0bee);
    for trial in 0..200usize {
        let d = 1 + rng.gen_range(100) as usize;
        let nt = 4 * rng.gen_range(12) as usize + trial % 4;
        let ts: Vec<u32> = (0..nt).map(|_| rng.gen_range(d as u32)).collect();
        let us = random_vals(&mut rng, nt);
        let row: Vec<f64> = (0..d).map(|_| rng.next_f64() - 0.5).collect();
        let mut naive = 0.0f64;
        for (&t, &u) in ts.iter().zip(&us) {
            naive += u * row[t as usize];
        }
        // SAFETY: term ids were generated < d == row.len().
        let got = unsafe { kernel::sparse_dot_dense(&ts, &us, &row) };
        assert_eq!(naive.to_bits(), got.to_bits(), "trial {trial}");
    }
}

#[test]
fn versioned_scatter_resets_lazily() {
    // DIVI's epoch-versioned scatter: stale slots are reset on first
    // touch of the epoch, untouched slots keep their stale value, and
    // duplicates accumulate in order.
    let mut score = vec![99.0f64; 3];
    let mut version = vec![0u32; 3];
    let mut touched = Vec::new();
    // SAFETY: ids 5/6 lie in [lo, lo + score.len()) = [5, 8);
    // score/version are parallel length-3 arrays.
    unsafe {
        kernel::scatter_add_versioned(
            &mut score,
            &mut version,
            &mut touched,
            1,
            &[5, 6, 5],
            &[1.0, 2.0, 3.0],
            2.0,
            5,
        )
    };
    assert_eq!(touched, vec![0, 1]);
    assert_eq!(score[0], 8.0); // 2·1 + 2·3, stale 99 discarded
    assert_eq!(score[1], 4.0);
    assert_eq!(score[2], 99.0); // untouched slot keeps stale value
    assert_eq!(version, vec![1, 1, 0]);
}

/// End-to-end through a real index: a full-array gather routed the way
/// the assigners now do it (`InvIndex::gather_term`: dense tail rows
/// where available, kernel scatter elsewhere) must match the naive
/// per-posting loop bit for bit, and the dense block must mirror the
/// sparse postings exactly.
#[test]
fn invindex_gather_dense_aware_matches_naive() {
    // A corpus whose top term ids are near-universal, so the dense tail
    // activates (term ids are df-ascending after build_dataset).
    let mut rng = Pcg32::new(0x1d_ead_5eed);
    let n_docs = 60usize;
    let d = 12usize;
    let docs: Vec<Vec<(u32, u32)>> = (0..n_docs)
        .map(|_| {
            let mut row: Vec<(u32, u32)> = Vec::new();
            for t in 0..d as u32 {
                // Higher term id ⇒ higher df (roughly), topping out at
                // always-present.
                let p = 2 + t;
                if rng.gen_range(d as u32 + 2) < p {
                    row.push((t, 1 + rng.gen_range(4)));
                }
            }
            if row.is_empty() {
                row.push((0, 1));
            }
            row
        })
        .collect();
    let ds = build_dataset("kernel-e2e", d, &docs);
    let k = 7usize;
    let assign: Vec<u32> = (0..ds.n() as u32).map(|i| i % k as u32).collect();
    let mut out = update_means(&ds, &assign, k, None, None);
    // Mixed moving flags so the two-block layout is nontrivial.
    for (j, m) in out.means.moved.iter_mut().enumerate() {
        *m = j % 2 == 0;
    }
    let idx = InvIndex::build(&out.means, ds.d());
    let (dense_lo, _) = idx.dense_parts();
    assert!(
        dense_lo < ds.d(),
        "dense tail never activated — corpus not top-heavy enough"
    );

    // Dense rows mirror the sparse postings exactly.
    for s in dense_lo..ds.d() {
        let row = idx.dense_row(s).unwrap();
        let (ids, vals) = idx.postings(s);
        let mut mirror = vec![0.0f64; k];
        for (&c, &v) in ids.iter().zip(vals) {
            mirror[c as usize] = v;
        }
        for (a, b) in mirror.iter().zip(row) {
            assert_eq!(a.to_bits(), b.to_bits(), "term {s}");
        }
    }

    // Full gather per object: naive postings loop vs the dense-aware
    // kernel routing, bitwise.
    for i in 0..ds.n() {
        let (ts, us) = ds.x.row(i);
        let mut naive = vec![0.0f64; k];
        for (&t, &u) in ts.iter().zip(us) {
            let (ids, vals) = idx.postings(t as usize);
            for (&c, &v) in ids.iter().zip(vals) {
                naive[c as usize] += u * v;
            }
        }
        let mut routed = vec![0.0f64; k];
        let mut mult = 0u64;
        for (&t, &u) in ts.iter().zip(us) {
            mult += idx.gather_term(t as usize, u, &mut routed, false);
        }
        for (j, (a, b)) in naive.iter().zip(&routed).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "object {i} centroid {j}");
        }
        // The shared dispatch must charge exactly the naive count.
        let naive_mult: u64 = ts.iter().map(|&t| idx.mf(t as usize) as u64).sum();
        assert_eq!(mult, naive_mult, "object {i} mult accounting");

        // Moving-only (ICP G_1) path: bit-identical to a naive scan of
        // the moving prefixes, and never dense-routed.
        let mut naive_mov = vec![0.0f64; k];
        for (&t, &u) in ts.iter().zip(us) {
            let (ids, vals) = idx.postings_moving(t as usize);
            for (&c, &v) in ids.iter().zip(vals) {
                naive_mov[c as usize] += u * v;
            }
        }
        let mut routed_mov = vec![0.0f64; k];
        for (&t, &u) in ts.iter().zip(us) {
            idx.gather_term(t as usize, u, &mut routed_mov, true);
        }
        for (j, (a, b)) in naive_mov.iter().zip(&routed_mov).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "object {i} moving centroid {j}");
        }
    }
}
