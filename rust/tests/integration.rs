//! Integration tests: whole-system behavior across modules — corpus →
//! features → clustering → (equivalence, metrics, indexes) — plus
//! property-style sweeps with the crate's own RNG, and the PJRT runtime
//! path when artifacts are present.

use skm::algo::{run_clustering, AlgoKind, ClusterConfig};
use skm::coordinator::{audit_equivalence, preset, run_and_summarize};
use skm::corpus::{generate, read_uci_bow, tiny, CorpusSpec};
use skm::index::update_means;
use skm::metrics::nmi;
use skm::sparse::build_dataset;
use skm::ucs;
use skm::util::rng::Pcg32;

fn dataset(n_docs: usize, seed: u64) -> skm::sparse::Dataset {
    let c = generate(&CorpusSpec {
        n_docs,
        ..tiny(seed)
    });
    build_dataset("it", c.n_terms, &c.docs)
}

/// The repo's central claim: every algorithm is an exact acceleration.
/// Property-style sweep over seeds and K values for all 12 algorithms.
#[test]
fn equivalence_sweep_all_algorithms() {
    let mut failures = Vec::new();
    for trial in 0..3u64 {
        let ds = dataset(350 + 150 * trial as usize, 500 + trial);
        let k = 8 + 4 * trial as usize;
        let cfg = ClusterConfig {
            k,
            seed: 900 + trial,
            ..Default::default()
        };
        for &kind in AlgoKind::all() {
            if kind == AlgoKind::Mivi {
                continue;
            }
            let rep = audit_equivalence(kind, &ds, &cfg, 1e-9);
            if !rep.passed() {
                failures.push(format!(
                    "trial {trial} K={k} {}: {} divergences",
                    rep.algo, rep.divergences
                ));
            }
        }
    }
    assert!(failures.is_empty(), "{failures:?}");
}

/// Accelerations must also agree on the number of iterations (identical
/// trajectories, not just identical fixed points).
#[test]
fn trajectory_lengths_agree() {
    let ds = dataset(500, 321);
    let cfg = ClusterConfig {
        k: 12,
        seed: 77,
        ..Default::default()
    };
    let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);
    for kind in [AlgoKind::EsIcp, AlgoKind::TaIcp, AlgoKind::CsIcp, AlgoKind::Icp] {
        let out = run_clustering(kind, &ds, &cfg);
        assert_eq!(out.iterations(), base.iterations(), "{}", kind.name());
        assert!(out.converged);
        // Per-iteration change counts match exactly.
        let ch_a: Vec<usize> = base.logs.iter().map(|l| l.changes).collect();
        let ch_b: Vec<usize> = out.logs.iter().map(|l| l.changes).collect();
        assert_eq!(ch_a, ch_b, "{}", kind.name());
    }
}

/// UCI loader → clustering end-to-end on an in-memory bag-of-words file.
#[test]
fn uci_corpus_end_to_end() {
    // Synthesize a corpus, serialize it to the UCI format, read it back,
    // and verify the datasets match.
    let c = generate(&tiny(31));
    let mut text = format!(
        "{}\n{}\n{}\n",
        c.n_docs(),
        c.n_terms,
        c.docs.iter().map(|d| d.len()).sum::<usize>()
    );
    for (i, doc) in c.docs.iter().enumerate() {
        for &(t, cnt) in doc {
            text.push_str(&format!("{} {} {}\n", i + 1, t + 1, cnt));
        }
    }
    let rt = read_uci_bow(text.as_bytes(), None).unwrap();
    assert_eq!(rt.docs, c.docs);
    let ds = build_dataset("uci", rt.n_terms, &rt.docs);
    let cfg = ClusterConfig {
        k: 8,
        seed: 4,
        ..Default::default()
    };
    let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    assert!(out.converged);
    assert!(out.objective > 0.0);
}

/// Clustering quality sanity: with planted topics, the solution should
/// correlate with the ground truth (NMI well above random).
#[test]
fn recovers_planted_topics() {
    let spec = CorpusSpec {
        n_docs: 600,
        n_topics: 10,
        anchor_prob: 0.5,
        ..tiny(88)
    };
    let c = generate(&spec);
    let ds = build_dataset("t", c.n_terms, &c.docs);
    let cfg = ClusterConfig {
        k: 10,
        seed: 3,
        ..Default::default()
    };
    let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    let score = nmi(&out.assign, &c.labels);
    assert!(score > 0.5, "NMI vs planted topics = {score}");
}

/// Preset workloads materialize with the advertised statistics.
#[test]
fn preset_statistics() {
    let p = preset("pubmed-like", 7, Some(0.05)).unwrap();
    let ds = p.dataset();
    assert!(ds.n() > 500);
    // K ≈ N/100 as in the paper's setting.
    assert!((p.k as f64 - ds.n() as f64 / 100.0).abs() <= 1.0 + ds.n() as f64 * 0.01);
    // Sparse in the paper's sense.
    assert!(ds.sparsity_indicator() < 0.1);
}

/// Objective is non-decreasing and CPR non-increasing (late vs early)
/// for the filter algorithms on a moderately sized run.
#[test]
fn run_invariants() {
    let ds = dataset(700, 654);
    let cfg = ClusterConfig {
        k: 14,
        seed: 21,
        ..Default::default()
    };
    for kind in [AlgoKind::EsIcp, AlgoKind::CsIcp, AlgoKind::TaIcp] {
        let (out, summary) = run_and_summarize(kind, &ds, &cfg);
        for w in out.logs.windows(2) {
            assert!(
                w[1].objective >= w[0].objective - 1e-9,
                "{}: objective decreased",
                kind.name()
            );
        }
        let early = out.logs[1].cpr; // after filters activate
        let late = out.logs.last().unwrap().cpr;
        assert!(
            late <= early + 1e-12,
            "{}: CPR grew {early} -> {late}",
            kind.name()
        );
        assert!(summary.converged);
    }
}

/// The ES upper bound is valid: for random (object, centroid) pairs the
/// bound from the folded index is ≥ the exact similarity.
#[test]
fn es_bound_validity_property() {
    use skm::index::EsIndex;
    let ds = dataset(400, 777);
    let cfg = ClusterConfig {
        k: 10,
        seed: 5,
        max_iters: 3,
        ..Default::default()
    };
    let out = run_clustering(AlgoKind::Mivi, &ds, &cfg);
    let upd = update_means(&ds, &out.assign, 10, None, None);
    let d = ds.d();
    let mut rng = Pcg32::new(2);
    for &t_frac in &[0.0, 0.5, 0.8, 0.95] {
        let t_th = (d as f64 * t_frac) as usize;
        let v_th = 0.05 + rng.next_f64() * 0.2;
        let idx = EsIndex::build(&upd.means, t_th, v_th);
        let mut rho = vec![0.0f64; 10];
        for _ in 0..50 {
            let i = rng.gen_range(ds.n() as u32) as usize;
            let (ts, vs) = ds.x.row(i);
            let p0 = ts.partition_point(|&t| (t as usize) < t_th);
            let y_base: f64 = vs[p0..].iter().map(|u| u * v_th).sum();
            rho.iter_mut().for_each(|r| *r = y_base);
            for (&t, &u) in ts[..p0].iter().zip(&vs[..p0]) {
                let (ids, vals) = idx.r1.postings(t as usize);
                for (&c, &v) in ids.iter().zip(vals) {
                    rho[c as usize] += u * v_th * v;
                }
            }
            for (&t, &u) in ts[p0..].iter().zip(&vs[p0..]) {
                let (ids, vals) = idx.r2.postings(t as usize);
                for (&c, &v) in ids.iter().zip(vals) {
                    rho[c as usize] += u * v_th * v;
                }
            }
            for j in 0..10 {
                let exact = ds.x.row_dot_dense(i, &upd.means.m.row_dense(j));
                assert!(
                    rho[j] >= exact - 1e-9,
                    "bound violated: t_th={t_th} v_th={v_th} i={i} j={j}: {} < {exact}",
                    rho[j]
                );
            }
        }
    }
}

/// Zipf + concentration UCs hold on the preset corpora (the premise of
/// the whole design).
#[test]
fn ucs_hold_on_presets() {
    let p = preset("pubmed-like", 7, Some(0.03)).unwrap();
    let ds = p.dataset();
    let df: Vec<f64> = ds.df.iter().map(|&x| x as f64).collect();
    let (alpha, r2) = ucs::zipf_exponent(&ucs::rank_frequency(&df), 80);
    assert!(alpha > 0.3 && r2 > 0.75, "alpha={alpha} r2={r2}");

    let cfg = ClusterConfig {
        k: p.k.max(4),
        seed: 1,
        max_iters: 15,
        ..Default::default()
    };
    let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    let upd = update_means(&ds, &out.assign, cfg.k, None, None);
    assert!(ucs::concentration_count(&upd.means) > 0);
    let curve = ucs::cps_curve(&ds, &upd.means, &out.assign, 50);
    assert!(curve.value_at(0.5) > 0.7, "CPS(0.5)={}", curve.value_at(0.5));
}

/// Runtime end-to-end (requires `make artifacts` and the `pjrt`
/// feature; skips otherwise so the default offline build stays green).
#[test]
fn pjrt_runtime_integration() {
    use skm::runtime::{PjrtRuntime, BLOCK_B, BLOCK_D, BLOCK_K};
    let dir = PjrtRuntime::default_dir();
    if !dir.join("kmeans_step.hlo.txt").exists() {
        eprintln!("skipping pjrt_runtime_integration: artifacts not built");
        return;
    }
    let mut rt = match PjrtRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping pjrt_runtime_integration: {e}");
            return;
        }
    };
    // Random unit rows; iterate the dense step and check the objective
    // is monotone and assignments stabilize.
    let mut rng = Pcg32::new(99);
    let mut make_rows = |rows: usize| {
        let mut x = vec![0.0f32; rows * BLOCK_D];
        for r in 0..rows {
            let mut norm = 0.0f32;
            for t in 0..BLOCK_D {
                let v = rng.next_f64() as f32;
                x[r * BLOCK_D + t] = v;
                norm += v * v;
            }
            let norm = norm.sqrt();
            for t in 0..BLOCK_D {
                x[r * BLOCK_D + t] /= norm;
            }
        }
        x
    };
    let x = make_rows(BLOCK_B);
    let mut m = make_rows(BLOCK_K);
    let mut prev_obj = f32::NEG_INFINITY;
    let mut last_assign = Vec::new();
    for _ in 0..8 {
        let (assign, new_m, obj) = rt.kmeans_step(&x, &m).expect("kmeans_step");
        assert!(obj >= prev_obj - 1e-3, "objective decreased: {prev_obj} -> {obj}");
        prev_obj = obj;
        m = new_m;
        last_assign = assign;
    }
    // Converged assignments are a valid labeling.
    assert_eq!(last_assign.len(), BLOCK_B);
    assert!(last_assign.iter().all(|&a| (a as usize) < BLOCK_K));
}
