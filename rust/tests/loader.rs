//! Dedicated coverage for the UCI bag-of-words loader
//! (`corpus::loader`): a hand-written file round-tripped from disk
//! through `read_uci_bow_file` into a clustering-ready `Dataset`,
//! including comment lines, the 1-based→0-based id conversion, and the
//! malformed-input error surface.

use skm::corpus::{read_uci_bow, read_uci_bow_file};
use skm::sparse::build_dataset;
use std::io::Write;
use std::path::PathBuf;

/// A hand-written docword file: 4 docs over a 6-term vocabulary, with
/// comment lines (both `#` and `%` styles), blank lines, and 1-based
/// ids throughout. All six terms occur (term 6 only via doc 2).
const HAND_WRITTEN: &str = "\
# hand-written UCI bag-of-words sample
% headers: N, D, NNZ
4

6
8
# doc term count (all ids 1-based)
1 1 2
1 3 1
2 2 4

2 6 1
3 1 1
% a comment between triples
3 4 2
4 5 3
4 1 1
";

fn temp_file(name: &str, contents: &str) -> PathBuf {
    let path = std::env::temp_dir().join(format!("skm_loader_{}_{name}", std::process::id()));
    let mut f = std::fs::File::create(&path).expect("create temp file");
    f.write_all(contents.as_bytes()).expect("write temp file");
    path
}

#[test]
fn hand_written_file_round_trips_from_disk() {
    let path = temp_file("roundtrip.txt", HAND_WRITTEN);
    let c = read_uci_bow_file(path.to_str().unwrap(), None).expect("parse hand-written file");
    std::fs::remove_file(&path).ok();

    assert_eq!(c.n_docs(), 4);
    assert_eq!(c.n_terms, 6);
    // 1-based ids converted to 0-based, rows sorted by term.
    assert_eq!(c.docs[0], vec![(0, 2), (2, 1)]);
    assert_eq!(c.docs[1], vec![(1, 4), (5, 1)]);
    assert_eq!(c.docs[2], vec![(0, 1), (3, 2)]);
    assert_eq!(c.docs[3], vec![(0, 1), (4, 3)]);

    // And the corpus feeds the full feature pipeline: term 6 (1-based)
    // occurs once, term 1 in three docs — df-ascending relabeling puts
    // the df=3 term last.
    let ds = build_dataset("hand", c.n_terms, &c.docs);
    assert_eq!(ds.n(), 4);
    assert_eq!(ds.d(), 6); // terms 1..6 all occur (term 6 via doc 2)
    assert!(ds.df.windows(2).all(|w| w[0] <= w[1]));
    assert_eq!(*ds.df.last().unwrap(), 3);
    for i in 0..ds.n() {
        let norm = ds.x.row_norm(i);
        assert!(norm == 0.0 || (norm - 1.0).abs() < 1e-12, "row {i}: {norm}");
    }
}

#[test]
fn max_docs_truncates_file_reads() {
    let path = temp_file("truncate.txt", HAND_WRITTEN);
    let c = read_uci_bow_file(path.to_str().unwrap(), Some(2)).expect("parse truncated");
    std::fs::remove_file(&path).ok();
    assert_eq!(c.n_docs(), 2);
    assert_eq!(c.docs[1], vec![(1, 4), (5, 1)]);
}

#[test]
fn malformed_lines_error_loudly() {
    // A triple with a non-numeric count.
    let bad_count = "2\n3\n2\n1 1 two\n2 2 1\n";
    let err = read_uci_bow(bad_count.as_bytes(), None).unwrap_err();
    assert!(format!("{err:#}").contains("count"), "unexpected error: {err:#}");

    // A triple missing its count field.
    let short = "1\n2\n1\n1 1\n";
    assert!(read_uci_bow(short.as_bytes(), None).is_err());

    // A non-numeric header.
    let bad_header = "x\n2\n1\n1 1 1\n";
    let err = read_uci_bow(bad_header.as_bytes(), None).unwrap_err();
    assert!(format!("{err:#}").contains('N'), "unexpected error: {err:#}");

    // Ids out of the declared ranges (0 is invalid: ids are 1-based).
    for bad in ["1\n2\n1\n0 1 1\n", "1\n2\n1\n1 3 1\n", "2\n2\n1\n3 1 1\n"] {
        assert!(read_uci_bow(bad.as_bytes(), None).is_err(), "{bad:?}");
    }

    // NNZ header disagreeing with the triple count.
    let mismatch = "1\n2\n5\n1 1 1\n";
    let err = read_uci_bow(mismatch.as_bytes(), None).unwrap_err();
    assert!(
        format!("{err:#}").contains("NNZ"),
        "unexpected error: {err:#}"
    );

    // Comments must not count as triples for the NNZ check.
    let commented = "1\n2\n1\n# not a triple\n1 1 1\n% trailing comment\n";
    assert!(read_uci_bow(commented.as_bytes(), None).is_ok());

    // Missing headers entirely.
    assert!(read_uci_bow("# only comments\n".as_bytes(), None).is_err());
}

/// Hostile headers (ISSUE §Robustness satellite): forged N/D/NNZ
/// declarations must be rejected up front with a typed error — before
/// any allocation proportional to the declared sizes — never a panic
/// or an OOM.
#[test]
fn hostile_headers_are_rejected_before_allocation() {
    use skm::corpus::loader::{MAX_DECLARED_DOCS, MAX_DECLARED_NNZ};
    use skm::error::SkmError;

    let cases: &[(&str, String)] = &[
        // N = usize::MAX parses but blows the document cap.
        ("N at usize::MAX", format!("{}\n2\n1\n", usize::MAX)),
        // One past the cap.
        ("N just over cap", format!("{}\n2\n1\n", MAX_DECLARED_DOCS + 1)),
        // 2^64 does not even parse as usize.
        ("N overflows u64", "18446744073709551616\n2\n1\n".to_string()),
        // D wider than u32 term ids.
        ("D over term cap", format!("1\n{}\n1\n", (u32::MAX as u64) + 1)),
        // NNZ beyond the absolute triple cap.
        ("NNZ over cap", format!("1\n2\n{}\n", MAX_DECLARED_NNZ + 1)),
        // NNZ structurally impossible: more triples than the N·D grid.
        ("NNZ over N·D", "3\n4\n13\n".to_string()),
        // Negative headers are not usize.
        ("negative N", "-1\n2\n1\n".to_string()),
    ];
    for (tag, text) in cases {
        let err = read_uci_bow(text.as_bytes(), None).unwrap_err();
        assert!(
            matches!(err, SkmError::MalformedCorpus { .. }),
            "{tag}: {err}"
        );
        assert_eq!(err.exit_code(), 1, "{tag}");
        // max_docs truncation must not bypass the caps.
        assert!(read_uci_bow(text.as_bytes(), Some(1)).is_err(), "{tag}");
    }

    // Headers-only file: N declares 10M docs (past PREALLOC_DOC_CAP,
    // under MAX_DECLARED_DOCS) and NNZ triples that never arrive — the
    // up-front reservation stays at the prealloc cap and the missing
    // triples are a typed mismatch, reported before the final
    // resize_with could materialize the forged N.
    let truncated = "10000000\n50\n200000000\n";
    let err = read_uci_bow(truncated.as_bytes(), None).unwrap_err();
    assert!(err.to_string().contains("NNZ"), "{err}");

    // A maximal-but-legal tiny file still parses: caps reject forged
    // sizes, not honest ones.
    let honest = "2\n2\n4\n1 1 1\n1 2 1\n2 1 1\n2 2 1\n";
    let c = read_uci_bow(honest.as_bytes(), None).unwrap();
    assert_eq!(c.n_docs(), 2);
}
