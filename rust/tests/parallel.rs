//! Determinism suite for the sharded multi-threaded engine
//! (`algo::par`): the parallel path must be **bit-identical** to the
//! serial reference path — same assignments, same per-iteration
//! objective trajectory, same merged operation counters — for every
//! algorithm, thread count, and shard size.

use skm::algo::{run_clustering, run_clustering_with, AlgoKind, ClusterConfig, ParConfig};
use skm::corpus::{generate, tiny, CorpusSpec};
use skm::metrics::nmi;
use skm::sparse::build_dataset;

fn dataset(n_docs: usize, seed: u64) -> skm::sparse::Dataset {
    let c = generate(&CorpusSpec {
        n_docs,
        ..tiny(seed)
    });
    build_dataset("par", c.n_terms, &c.docs)
}

/// Satellite: bit-identical assignments, per-iteration objectives, and
/// NMI between serial and `threads ∈ {2, 4, 7}` across ≥3 seeds and
/// ≥3 `AlgoKind`s (including `EsIcp`).
#[test]
fn determinism_across_threads_seeds_and_kinds() {
    let kinds = [
        AlgoKind::EsIcp,
        AlgoKind::Mivi,
        AlgoKind::TaIcp,
        AlgoKind::CsIcp,
    ];
    for (trial, &seed) in [31u64, 32, 33].iter().enumerate() {
        let ds = dataset(300 + 100 * trial, 700 + seed);
        let cfg = ClusterConfig {
            k: 9 + trial,
            seed,
            ..Default::default()
        };
        for &kind in &kinds {
            let serial = run_clustering(kind, &ds, &cfg);
            for threads in [2usize, 4, 7] {
                let par = run_clustering_with(
                    kind,
                    &ds,
                    &cfg,
                    &ParConfig::with_threads(threads),
                );
                let tag = format!("{} seed={seed} threads={threads}", kind.name());
                // Bit-identical assignments …
                assert_eq!(par.assign, serial.assign, "{tag}: assignments diverged");
                // … hence NMI exactly 1 …
                assert!(
                    (nmi(&par.assign, &serial.assign) - 1.0).abs() < 1e-12,
                    "{tag}: NMI != 1"
                );
                // … identical trajectory length and per-iteration
                // objectives, compared bitwise, not with a tolerance.
                assert_eq!(par.iterations(), serial.iterations(), "{tag}");
                for (a, b) in par.logs.iter().zip(&serial.logs) {
                    assert_eq!(
                        a.objective.to_bits(),
                        b.objective.to_bits(),
                        "{tag}: objective diverged at iteration {}",
                        a.iter
                    );
                    assert_eq!(a.changes, b.changes, "{tag}: change counts diverged");
                }
                assert_eq!(
                    par.objective.to_bits(),
                    serial.objective.to_bits(),
                    "{tag}: final objective"
                );
            }
        }
    }
}

/// Satellite: merged per-thread `OpCounters` exactly equal the serial
/// counters (mult / branch / cold-touch / candidate / exact-sim / sqrt
/// totals) for MIVI and ES-ICP on a synthetic corpus.
#[test]
fn counter_merge_exactly_matches_serial() {
    let ds = dataset(420, 811);
    let cfg = ClusterConfig {
        k: 11,
        seed: 5,
        ..Default::default()
    };
    for kind in [AlgoKind::Mivi, AlgoKind::EsIcp] {
        let serial = run_clustering(kind, &ds, &cfg);
        for threads in [2usize, 4, 7] {
            let par =
                run_clustering_with(kind, &ds, &cfg, &ParConfig::with_threads(threads));
            assert_eq!(
                par.logs.len(),
                serial.logs.len(),
                "{} threads={threads}",
                kind.name()
            );
            for (a, b) in par.logs.iter().zip(&serial.logs) {
                assert_eq!(
                    a.counters, b.counters,
                    "{} threads={threads}: counters diverged at iteration {}",
                    kind.name(),
                    a.iter
                );
            }
            assert_eq!(par.total_mult(), serial.total_mult());
        }
    }
}

/// Every one of the 12 algorithm kinds runs its assignment step through
/// the sharded engine and lands on the serial solution exactly.
#[test]
fn all_twelve_kinds_sharded_exactly() {
    let ds = dataset(320, 900);
    let cfg = ClusterConfig {
        k: 10,
        seed: 17,
        ..Default::default()
    };
    let par = ParConfig::with_threads(3);
    for &kind in AlgoKind::all() {
        let serial = run_clustering(kind, &ds, &cfg);
        let sharded = run_clustering_with(kind, &ds, &cfg, &par);
        assert_eq!(sharded.assign, serial.assign, "{}", kind.name());
        assert_eq!(
            sharded.objective.to_bits(),
            serial.objective.to_bits(),
            "{}",
            kind.name()
        );
        assert_eq!(sharded.iterations(), serial.iterations(), "{}", kind.name());
    }
}

/// Shard size must not matter either: odd shard sizes that split the
/// corpus unevenly (including shards much smaller than N/threads)
/// reproduce the serial run bit-for-bit.
#[test]
fn shard_size_is_immaterial() {
    let ds = dataset(310, 1000);
    let cfg = ClusterConfig {
        k: 8,
        seed: 23,
        ..Default::default()
    };
    for kind in [AlgoKind::EsIcp, AlgoKind::Ding, AlgoKind::Divi] {
        let serial = run_clustering(kind, &ds, &cfg);
        for shard in [1usize, 23, 97, 512] {
            let par = ParConfig { threads: 4, shard };
            let out = run_clustering_with(kind, &ds, &cfg, &par);
            assert_eq!(
                out.assign,
                serial.assign,
                "{} shard={shard}",
                kind.name()
            );
            assert_eq!(out.objective.to_bits(), serial.objective.to_bits());
            assert_eq!(out.total_mult(), serial.total_mult());
        }
    }
}

/// The engine's config plumbing: `ParConfig::from_env` defaults to
/// serial when the knobs are unset, and `--threads`-style explicit
/// configs clamp zero to serial.
#[test]
fn par_config_defaults() {
    std::env::remove_var("SKM_THREADS");
    std::env::remove_var("SKM_SHARD");
    let p = ParConfig::from_env();
    assert!(!p.is_parallel());
    assert_eq!(p.shard, 0);
    assert!(!ParConfig::with_threads(0).is_parallel());
    assert!(ParConfig::with_threads(2).is_parallel());
}
